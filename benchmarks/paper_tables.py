"""Reproduce the paper's Tables 2-6: each classifier x {C, PCA, SVD},
single machine vs N (virtual) machines.

MUST be invoked as its own process when --devices > 1 (sets XLA_FLAGS
before jax imports).  Prints CSV: table,algo,transform,devices,A,P,R,time_s.
"""
import argparse
import json
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=20000)
ap.add_argument("--n-test", type=int, default=4000)
ap.add_argument("--devices", type=int, default=1)
ap.add_argument("--algos", default="nb,lr,dt,rf,gbt")
ap.add_argument("--transforms", default="none,pca,svd")
ap.add_argument("--gbt-mllib2018", action="store_true",
                help="also run the paper-faithful binary-GBT pathology")
ap.add_argument("--out", default="")
args = ap.parse_args()

if args.devices > 1:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + os.environ.get("XLA_FLAGS", ""))

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time                                     # noqa: E402

import jax                                      # noqa: E402
import jax.numpy as jnp                         # noqa: E402

from repro.core import ALGORITHMS, PCA, SVD, metrics            # noqa: E402
from repro.core.estimator import DistContext, pad_examples      # noqa: E402
from repro.data.pipeline import make_dataset                    # noqa: E402
from repro.sharding.axes import make_test_mesh                  # noqa: E402

TABLE_OF = {"nb": 2, "lr": 3, "dt": 4, "rf": 5, "gbt": 6,
            "svm": "extra", "ada": "extra"}


def main():
    mesh = make_test_mesh(args.devices, 1) if args.devices > 1 else None
    ctx = DistContext(mesh=mesh) if mesh is not None else DistContext()
    ds = make_dataset(args.n, args.n_test, seed=0)
    rows = []
    print("table,algo,transform,devices,accuracy,precision,recall,time_s")
    for tname in args.transforms.split(","):
        if tname == "none":
            Xtr, Xte = ds["X_train"], ds["X_test"]
        elif tname == "pca":
            tr = PCA(16)
            p, Xtr = tr.fit_transform(ds["X_train"], ctx)
            Xte = tr.transform(p, ds["X_test"])
        else:
            tr = SVD(16)
            p, Xtr = tr.fit_transform(ds["X_train"], ctx)
            Xte = tr.transform(p, ds["X_test"])
        ytr, yte = ds["y_train"], ds["y_test"]
        if mesh is not None:
            Xp, yp, w = pad_examples(Xtr, ytr, args.devices)
            Xp, yp = ctx.shard_batch(Xp, yp)
        else:
            Xp, yp, w = Xtr, ytr, None

        algo_list = args.algos.split(",")
        for name in algo_list:
            algo = ALGORITHMS[name](n_classes=6)
            t0 = time.time()
            params = algo.fit(Xp, yp, ctx, weights=w,
                              key=jax.random.PRNGKey(1))
            jax.block_until_ready(jax.tree.leaves(params)[0])
            dt = time.time() - t0
            pred = algo.predict(params, Xte)
            rep = metrics.evaluate(yte, pred, 6)
            row = dict(table=TABLE_OF[name], algo=name, transform=tname,
                       devices=args.devices, accuracy=round(rep["accuracy"], 4),
                       precision=round(rep["precision"], 4),
                       recall=round(rep["recall"], 4), time_s=round(dt, 2))
            rows.append(row)
            print(",".join(str(row[k]) for k in
                           ("table", "algo", "transform", "devices",
                            "accuracy", "precision", "recall", "time_s")))
        if args.gbt_mllib2018 and tname == "none":
            algo = ALGORITHMS["gbt"](n_classes=6)
            algo.mode = "mllib2018"
            t0 = time.time()
            params = algo.fit(Xp, yp, ctx, weights=w)
            jax.block_until_ready(jax.tree.leaves(params)[0])
            pred = algo.predict(params, Xte)
            rep = metrics.evaluate(yte, pred, 6)
            row = dict(table=6, algo="gbt_mllib2018", transform=tname,
                       devices=args.devices, accuracy=round(rep["accuracy"], 4),
                       precision=round(rep["precision"], 4),
                       recall=round(rep["recall"], 4),
                       time_s=round(time.time() - t0, 2))
            rows.append(row)
            print(",".join(str(row[k]) for k in
                           ("table", "algo", "transform", "devices",
                            "accuracy", "precision", "recall", "time_s")))
    if args.out:
        with open(args.out, "a") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
