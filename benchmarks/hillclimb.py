"""Perf-iteration driver: lower ONE (arch x shape) combo with experiment
knobs and report the roofline deltas vs the frozen baseline
(results/dryrun_single.jsonl).

    PYTHONPATH=src python benchmarks/hillclimb.py --arch jamba-1.5-large-398b \
        --shape train_4k --microbatches 16 --moment-dtype bfloat16 --tag mb16

Each invocation appends a record to results/hillclimb.jsonl so the
§Perf log in EXPERIMENTS.md is reproducible.
"""
import argparse
import json
import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time                                                     # noqa: E402

import jax                                                      # noqa: E402

from repro.configs import SHAPES_BY_NAME, get_config            # noqa: E402
from repro.launch import inputs as inputs_lib                   # noqa: E402
from repro.launch.dryrun import run_combo                       # noqa: E402
from repro.launch.flops import roofline_terms, step_flops, step_hbm_bytes  # noqa: E402
from repro.launch.hloparse import collective_bytes, tpu_faithful_total  # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.models.transformer import block_period               # noqa: E402
from repro.sharding import specs as specs_lib                   # noqa: E402
from repro.sharding.axes import axes_from_mesh                  # noqa: E402
from repro.train.loop import (TrainConfig, make_prefill,        # noqa: E402
                              make_serve_step, make_train_step)
from repro.train.optimizer import OptConfig                     # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--kv-dtype", default="")
    ap.add_argument("--q-chunk", type=int, default=1024)
    ap.add_argument("--window", type=int, default=-1,
                    help="override sliding window (-1: arch default)")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES_BY_NAME[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    axes = axes_from_mesh(mesh)
    fsdp = (not args.no_fsdp) and specs_lib.auto_fsdp(cfg, mesh, axes)

    if args.window >= 0:
        cfg = cfg.replace(sliding_window=args.window)
    elif shape.name == "long_500k" and not cfg.sliding_window:
        if any(k == "attn" for k, _ in cfg.layer_pattern()):
            cfg = cfg.replace(sliding_window=8192)
    if args.kv_dtype:
        cfg = cfg.replace(kv_dtype=args.kv_dtype)

    tc = TrainConfig(opt=OptConfig(moment_dtype=args.moment_dtype),
                     q_chunk=args.q_chunk, microbatches=args.microbatches,
                     zero1=args.zero1)

    t0 = time.time()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            step, *_ = make_train_step(cfg, mesh, tc, shape, fsdp=fsdp)
            state = inputs_lib.state_struct(cfg, mesh, fsdp, tc)
            batch = inputs_lib.batch_struct(cfg, shape, mesh)
            lowered = step.lower(state, batch)
        elif shape.kind == "prefill":
            pf, *_ = make_prefill(cfg, mesh, shape, q_chunk=args.q_chunk,
                                  fsdp=fsdp)
            lowered = pf.lower(inputs_lib.params_struct(cfg, mesh, fsdp),
                               inputs_lib.batch_struct(cfg, shape, mesh))
        else:
            st, *_ = make_serve_step(cfg, mesh, shape, fsdp=fsdp)
            token, cache, pos = inputs_lib.decode_structs(cfg, shape, mesh)
            lowered = st.lower(inputs_lib.params_struct(cfg, mesh, fsdp),
                               token, cache, pos)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    coll, counts = collective_bytes(compiled.as_text())
    fl = step_flops(cfg, shape)
    hb = step_hbm_bytes(cfg, shape, mesh, axes, fsdp)
    # moment dtype affects state traffic (step_hbm_bytes assumes 8B moments)
    if args.moment_dtype == "bfloat16" and shape.kind == "train":
        hb["moments"] = hb.get("moments", 0.0) / 2
        hb["total"] = hb["params"] * 4 + hb["moments"] * 2 + \
            hb["params"] * 2 * 2 + hb["act_carries"] * 3
    coll_dev = tpu_faithful_total(coll)
    rt = roofline_terms(fl["total"], hb["total"], coll_dev, mesh.devices.size)
    rec = {
        "tag": args.tag, "arch": args.arch, "shape": args.shape,
        "mesh": "2x16x16" if args.multi_pod else "16x16",
        "knobs": {"microbatches": args.microbatches, "zero1": args.zero1,
                  "moment_dtype": args.moment_dtype,
                  "kv_dtype": args.kv_dtype, "q_chunk": args.q_chunk,
                  "window": args.window, "fsdp": fsdp},
        "t_compile_s": round(time.time() - t0, 1),
        "temp_bytes_per_dev": getattr(mem, "temp_size_in_bytes", 0),
        "argument_bytes_per_dev": getattr(mem, "argument_size_in_bytes", 0),
        "collective_bytes": coll, "collective_counts": counts,
        "collective_bytes_dev": coll_dev,
        "analytic_flops_global": fl["total"],
        "analytic_hbm_bytes_dev": hb["total"],
        "roofline": rt,
    }
    print(json.dumps(rec, indent=1))
    os.makedirs("results", exist_ok=True)
    with open("results/hillclimb.jsonl", "a") as f:
        f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
