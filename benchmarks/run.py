"""Benchmark harness: one function per paper table + kernel microbenches.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV per the harness contract:
  * tables 2-6 (NB/LR/DT/RF/GBT x {C,PCA,SVD}), single vs 8 virtual devices
    (in subprocesses so device counts don't leak);
  * kernel microbenches (jnp oracle timings on CPU; Pallas bodies are
    validated via interpret mode in tests — wall-clock kernel timing needs
    real TPU);
  * the roofline table when dry-run records exist (results/*.jsonl).
"""
import argparse
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))


def bench(fn, *a, reps=3, warmup=1):
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*a))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*a))
    return (time.time() - t0) / reps * 1e6          # us


def kernel_microbench():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    xs = jnp.sort(jax.random.normal(key, (512, 5, 3000)), -1)
    us = bench(lambda: ops.band_stats(xs))
    print(f"kernel_band_stats_ref,{us:.0f},epochs_per_s={512/us*1e6:.0f}")
    X = jax.random.normal(key, (8192, 75))
    us = bench(lambda: ops.gram(X))
    print(f"kernel_gram_ref,{us:.0f},gflops={2*8192*75*75/us/1e3:.1f}")
    bins = jax.random.randint(key, (65536,), 0, 32)
    node = jax.random.randint(key, (65536,), 0, 32)
    stat = jax.random.normal(key, (65536, 6))
    us = bench(lambda: ops.hist(bins, node, stat, 32, 32))
    print(f"kernel_hist_ref,{us:.0f},melem_per_s={65536/us:.1f}")
    q = jax.random.normal(key, (1, 1024, 8, 128)) * 0.2
    us = bench(lambda: ops.swa_attention(q, q, q, window=256))
    print(f"kernel_swa_ref,{us:.0f},ktok_per_s={1024/us*1e3:.0f}")


def paper_tables(n, devices, extra=()):
    cmd = [sys.executable, os.path.join(ROOT, "benchmarks", "paper_tables.py"),
           "--n", str(n), "--devices", str(devices), *extra]
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    subprocess.check_call(cmd, env=env)


def roofline_table():
    import glob
    paths = sorted(glob.glob(os.path.join(ROOT, "results", "dryrun*.jsonl")))
    if not paths:
        print("roofline: no results/dryrun*.jsonl yet — run "
              "`python -m repro.launch.dryrun --all --out results/dryrun_single.jsonl`")
        return
    from benchmarks.roofline import load, report
    report(load(paths))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--n", type=int, default=0)
    args = ap.parse_args()
    n = args.n or (8000 if args.quick else 20000)

    print("== kernel microbenches (jnp oracle path on CPU) ==")
    kernel_microbench()

    print("\n== paper tables 2-6: single machine ==")
    paper_tables(n, 1, ("--gbt-mllib2018",))
    print("\n== paper tables 2-6: 8 virtual machines ==")
    paper_tables(n, 8)

    print("\n== roofline (from dry-run artifacts) ==")
    roofline_table()


if __name__ == "__main__":
    main()
