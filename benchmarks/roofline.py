"""Roofline reporter: reads launch/dryrun JSONL records and prints the
per-(arch x shape x mesh) three-term table (EXPERIMENTS.md §Roofline).

    PYTHONPATH=src python -m benchmarks.roofline results/dryrun_single.jsonl ...
"""
import json
import sys


def fmt(v, unit=""):
    if v == 0:
        return "0"
    for scale, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if abs(v) >= scale:
            return f"{v/scale:.2f}{suf}{unit}"
    return f"{v:.3g}{unit}"


def load(paths):
    recs = []
    for p in paths:
        with open(p) as f:
            for line in f:
                if line.strip():
                    recs.append(json.loads(line))
    return recs


def report(recs, file=sys.stdout):
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>10s} "
           f"{'MODELfl':>9s} {'useful%':>8s} {'temp/dev':>9s}")
    print(hdr, file=file)
    for r in recs:
        if not r.get("ok"):
            print(f"{r['arch']:24s} {r['shape']:12s} {r.get('mesh',''):8s} "
                  f"FAILED: {r.get('error','')[:60]}", file=file)
            continue
        rt = r["roofline"]
        useful = 100.0 * r["model_flops"] / max(r["analytic_flops_global"], 1)
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
              f"{rt['compute_s']:10.4g} {rt['memory_s']:10.4g} "
              f"{rt['collective_s']:10.4g} {rt['dominant']:>10s} "
              f"{fmt(r['model_flops']):>9s} {useful:7.1f}% "
              f"{fmt(r.get('temp_bytes_per_dev', 0), 'B'):>9s}", file=file)


def main():
    recs = load(sys.argv[1:] or ["results/dryrun_single.jsonl"])
    report(recs)
    bad = [r for r in recs if not r.get("ok")]
    print(f"\n{len(recs)-len(bad)}/{len(recs)} combos compiled OK")
    if bad:
        sys.exit(1)


if __name__ == "__main__":
    main()
