"""Serving engine integration tests (static batching over prefill+decode)."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import ServeEngine
from repro.sharding.axes import make_test_mesh


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("stablelm-1.6b")
    mesh = make_test_mesh()
    with jax.set_mesh(mesh):
        params = init_params(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, mesh, batch=2, bucket=32, max_total=64)
    return eng


def test_engine_serves_all_requests(engine):
    rng = np.random.default_rng(0)
    rids = [engine.submit(rng.integers(0, 100, size=rng.integers(4, 30)),
                          max_new_tokens=6) for _ in range(5)]
    with jax.set_mesh(engine.mesh):
        done = engine.run()
    assert set(rids) <= set(done)
    for rid in rids:
        r = done[rid]
        assert r.done and len(r.out_tokens) == 6
        assert all(0 <= t < engine.cfg.vocab_size for t in r.out_tokens)
    st = engine.stats()
    assert st["requests"] >= 5 and st["tokens"] >= 30
    assert st["ttft_mean_s"] >= 0 and st["throughput_tok_s"] > 0


def test_engine_deterministic_greedy(engine):
    prompt = np.arange(10) % 50
    with jax.set_mesh(engine.mesh):
        r1 = engine.submit(prompt, max_new_tokens=5)
        engine.run()
        r2 = engine.submit(prompt, max_new_tokens=5)
        engine.run()
    assert engine.finished[r1].out_tokens == engine.finished[r2].out_tokens
