"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import metrics
from repro.core.naive_bayes import NaiveBayes
from repro.core.trees import binarize, fit_bins
from repro.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


@given(st.integers(16, 200), st.integers(2, 6), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_confusion_matrix_mass_conservation(n, k, seed):
    key = jax.random.PRNGKey(seed)
    y = jax.random.randint(key, (n,), 0, k)
    p = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, k)
    cm = metrics.confusion_matrix(y, p, k)
    assert float(cm.sum()) == n
    rep = metrics.classification_report(cm)
    assert 0.0 <= rep["accuracy"] <= 1.0
    assert 0.0 <= rep["precision"] <= 1.0
    assert 0.0 <= rep["recall"] <= 1.0


@given(st.integers(0, 10_000))
@settings(**SETTINGS)
def test_perfect_prediction_metrics(seed):
    key = jax.random.PRNGKey(seed)
    y = jax.random.randint(key, (64,), 0, 4)
    rep = metrics.evaluate(y, y, 4)
    assert rep["accuracy"] == 1.0 and rep["recall"] == 1.0


@given(st.integers(32, 256), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_band_stats_order_invariants(t, seed):
    """On sorted data: min <= q25 <= median <= q75 <= max; iqr >= 0;
    std >= 0; energy >= 0; entropy >= 0."""
    key = jax.random.PRNGKey(seed)
    x = jnp.sort(jax.random.normal(key, (4, 5, t)) * 10, -1)
    s = ref.band_stats_ref(x)
    mn, med, mx = s[..., 5], s[..., 6], s[..., 7]
    q25, q75, iqr = s[..., 10], s[..., 11], s[..., 12]
    assert bool(jnp.all(mn <= q25 + 1e-5)) and bool(jnp.all(q25 <= med + 1e-5))
    assert bool(jnp.all(med <= q75 + 1e-5)) and bool(jnp.all(q75 <= mx + 1e-5))
    assert bool(jnp.all(iqr >= -1e-6))
    assert bool(jnp.all(s[..., 8] >= 0))        # std
    assert bool(jnp.all(s[..., 3] >= 0))        # energy
    assert bool(jnp.all(s[..., 4] >= -1e-5))    # entropy


@given(st.integers(1, 6), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_band_stats_scale_equivariance(scale_pow, seed):
    """mean/std/quantiles scale linearly; skew/kurtosis are scale-free."""
    key = jax.random.PRNGKey(seed)
    x = jnp.sort(jax.random.normal(key, (2, 5, 100)), -1)
    c = float(2 ** scale_pow)
    a = ref.band_stats_ref(x)
    b = ref.band_stats_ref(x * c)
    for idx in (0, 6, 8, 10, 11, 12):           # mean, median, std, q25, q75, iqr
        np.testing.assert_allclose(b[..., idx], a[..., idx] * c,
                                   rtol=1e-4, atol=1e-4)
    for idx in (9, 14):                          # skew, kurtosis scale-free
        np.testing.assert_allclose(b[..., idx], a[..., idx],
                                   rtol=1e-3, atol=1e-3)


@given(st.integers(2, 16), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_binarize_monotonic(n_bins, seed):
    key = jax.random.PRNGKey(seed)
    X = jax.random.normal(key, (128, 3))
    edges = fit_bins(X, n_bins)
    Xb = binarize(X, edges)
    assert int(Xb.max()) <= n_bins - 1 and int(Xb.min()) >= 0
    # monotonic: larger value -> bin index at least as large (per column)
    order = jnp.argsort(X[:, 0])
    assert bool(jnp.all(jnp.diff(Xb[order, 0].astype(jnp.int32)) >= 0))


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_nb_invariant_to_example_order(seed):
    key = jax.random.PRNGKey(seed)
    X = jax.random.normal(key, (128, 8))
    y = jax.random.randint(jax.random.fold_in(key, 1), (128,), 0, 3)
    perm = jax.random.permutation(jax.random.fold_in(key, 2), 128)
    nb = NaiveBayes(3)
    p1 = nb.fit(X, y)
    p2 = nb.fit(X[perm], y[perm])
    np.testing.assert_allclose(p1["mean"], p2["mean"], rtol=1e-5, atol=1e-5)


@given(st.integers(1, 8), st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_hist_shard_additivity(shards, seed):
    """The treeAggregate contract: hist(full) == sum of hist(shards)."""
    key = jax.random.PRNGKey(seed)
    n = 64 * shards
    bins = jax.random.randint(key, (n,), 0, 8)
    node = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 4)
    stat = jax.random.normal(jax.random.fold_in(key, 2), (n, 2))
    full = ref.hist_ref(bins, node, stat, 4, 8)
    parts = sum(ref.hist_ref(bins[i::shards], node[i::shards],
                             stat[i::shards], 4, 8) for i in range(shards))
    np.testing.assert_allclose(full, parts, rtol=1e-5, atol=1e-5)
