"""int8 KV cache: quantization round-trip + decode-path accuracy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Context, decode_step, init_params, prefill
from repro.models.attention import dequantize_kv, quantize_kv
from repro.models.kvcache import cache_layout, grow_cache
from repro.sharding.axes import SINGLE_POD, make_test_mesh


def test_quantize_roundtrip(rng):
    x = jax.random.normal(rng, (2, 16, 4, 64)) * 3.0
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 16, 4, 1)
    xd = dequantize_kv(q, s, jnp.float32)
    err = jnp.abs(xd - x).max() / jnp.abs(x).max()
    assert float(err) < 0.02


def test_quantize_scale_invariance(rng):
    """Quantization error is relative: scaling x scales the output."""
    x = jax.random.normal(rng, (1, 8, 2, 32))
    q1, s1 = quantize_kv(x)
    q2, s2 = quantize_kv(x * 100.0)
    np.testing.assert_array_equal(q1, q2)
    np.testing.assert_allclose(np.asarray(s2, np.float32),
                               np.asarray(s1, np.float32) * 100.0, rtol=1e-2)


def test_int8_cache_layout():
    cfg = get_smoke_config("llama3.2-3b").replace(kv_dtype="int8")
    lay = cache_layout(cfg, 2, 64)
    sub = lay["pos0"]
    assert sub["k"][1] == jnp.int8
    assert "k_scale" in sub and "v_scale" in sub


def test_int8_decode_close_to_bf16(rng):
    base = get_smoke_config("llama3.2-3b")
    mesh = make_test_mesh()
    S = 32
    tokens = jax.random.randint(rng, (2, S), 0, base.vocab_size)
    outs = {}
    with jax.set_mesh(mesh):
        for name, cfg in (("ref", base), ("int8", base.replace(kv_dtype="int8"))):
            params = init_params(rng, cfg)
            ctx = Context(mesh=mesh, axes=SINGLE_POD, batch_sharded=False,
                          q_chunk=16)
            _lg, cache = prefill(params, cfg, tokens[:, :-1], ctx)
            cache = grow_cache(cache, cfg, 2, S)
            got, _ = decode_step(params, cfg, tokens[:, -1:], cache,
                                 jnp.int32(S - 1), ctx)
            outs[name] = np.asarray(got)
    err = np.abs(outs["ref"] - outs["int8"]).max() / \
        (np.abs(outs["ref"]).max() + 1e-9)
    assert err < 0.05, err
