import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

# tests run on the real 1-device CPU platform (the 512-device override is
# ONLY for launch/dryrun.py as a process entrypoint)
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def sleep_dataset():
    """Small shared dataset for classifier tests."""
    from repro.data.pipeline import make_dataset
    return make_dataset(6000, 1500, chunk=3000, use_kernel=False, seed=3)
