"""Per-slot decode positions (the continuous-batching enabler):

* vector positions == scalar position when equal;
* MIXED positions: each slot's logits match a separate per-sequence decode
  at its own offset (two requests at different generation depths share one
  decode program).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Context, decode_step, init_params, prefill
from repro.models.kvcache import grow_cache
from repro.sharding.axes import SINGLE_POD, make_test_mesh

S = 24


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "llama3.2-3b"])
def test_vector_equals_scalar_positions(arch, rng):
    cfg = get_smoke_config(arch)
    mesh = make_test_mesh()
    tokens = jax.random.randint(rng, (2, S), 0, cfg.vocab_size)
    with jax.set_mesh(mesh):
        params = init_params(rng, cfg)
        ctx = Context(mesh=mesh, axes=SINGLE_POD, batch_sharded=False, q_chunk=8)
        _, cache = prefill(params, cfg, tokens[:, :-1], ctx)
        cache = grow_cache(cache, cfg, 2, S)
        a, _ = decode_step(params, cfg, tokens[:, -1:], cache,
                           jnp.int32(S - 1), ctx)
        b, _ = decode_step(params, cfg, tokens[:, -1:], cache,
                           jnp.full((2,), S - 1, jnp.int32), ctx)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_mixed_positions_match_per_sequence(rng):
    """Seq 0 decodes at position S-1, seq 1 at position S-3, in ONE batched
    step; results must match the two independent single-sequence decodes."""
    cfg = get_smoke_config("stablelm-1.6b")
    mesh = make_test_mesh()
    toks = jax.random.randint(rng, (2, S), 0, cfg.vocab_size)
    offs = [S - 1, S - 3]
    with jax.set_mesh(mesh):
        params = init_params(rng, cfg)
        ctx = Context(mesh=mesh, axes=SINGLE_POD, batch_sharded=False, q_chunk=8)
        # independent references (batch of 1 each, prompt = offs[i] tokens)
        refs = []
        for i, off in enumerate(offs):
            _, c = prefill(params, cfg, toks[i:i + 1, :off], ctx)
            c = grow_cache(c, cfg, 1, S)
            lg, _ = decode_step(params, cfg, toks[i:i + 1, off:off + 1], c,
                                jnp.int32(off), ctx)
            refs.append(np.asarray(lg))
        # batched mixed-position decode: build the shared cache by stacking
        # each sequence's prefill cache
        caches = []
        for i, off in enumerate(offs):
            _, c = prefill(params, cfg, toks[i:i + 1, :off], ctx)
            # pad the shorter prompt's cache to a common W before stacking
            c = grow_cache(c, cfg, 1, S)
            caches.append(c)
        cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1), *caches)
        step_tok = jnp.stack([toks[0, offs[0]], toks[1, offs[1]]])[:, None]
        lg, _ = decode_step(params, cfg, step_tok, cache,
                            jnp.asarray(offs, jnp.int32), ctx)
    got = np.asarray(lg)
    for i in range(2):
        np.testing.assert_allclose(got[i:i + 1], refs[i], rtol=2e-3, atol=2e-3)
