"""End-to-end behaviour tests for the paper's system.

1. The full sleep pipeline (synthesize -> featurize -> distribute -> classify
   -> evaluate) hits the paper's accuracy regime.
2. LM training end-to-end: loss decreases over a few dozen steps.
3. Microbatched grad accumulation == single-batch step.
4. True multi-(virtual-)device runs via subprocess: single vs 2 machines
   produce the same models (the paper's central scalability claim).
5. The serving driver runs end-to-end.
"""
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.configs.shapes import InputShape
from repro.data.pipeline import token_stream
from repro.sharding.axes import make_test_mesh
from repro.train.loop import TrainConfig, init_state, make_train_step
from repro.train.optimizer import OptConfig

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_sleep_pipeline_end_to_end(sleep_dataset):
    from repro.core import ALGORITHMS, metrics
    from repro.core.estimator import DistContext
    ds = sleep_dataset
    algo = ALGORITHMS["lr"](n_classes=6)
    p = algo.fit(ds["X_train"], ds["y_train"], DistContext())
    rep = metrics.evaluate(ds["y_test"], algo.predict(p, ds["X_test"]), 6)
    # the paper's LR row: A=0.823 P=0.730 R=0.886 — same regime
    assert 0.74 < rep["accuracy"] < 0.92


def test_lm_training_loss_decreases(rng):
    cfg = get_smoke_config("stablelm-1.6b")
    mesh = make_test_mesh()
    shape = InputShape("t", 128, 4, "train")
    tc = TrainConfig(opt=OptConfig(lr=2e-3, warmup_steps=5, total_steps=40),
                     q_chunk=64, microbatches=2)
    with jax.set_mesh(mesh):
        step, *_ = make_train_step(cfg, mesh, tc, shape, fsdp=False)
        state = init_state(rng, cfg, tc)
        losses = []
        for i, batch in zip(range(40), token_stream(cfg, 4, 128, seed=2)):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
    assert all(jnp.isfinite(jnp.asarray(losses)))


def test_microbatching_matches_full_batch(rng):
    """k-microbatch grad accumulation == single-batch step (same update)."""
    cfg = get_smoke_config("llama3.2-3b")
    mesh = make_test_mesh()
    shape = InputShape("t", 64, 4, "train")
    batch = next(token_stream(cfg, 4, 64, seed=7))
    outs = []
    with jax.set_mesh(mesh):
        for k in (1, 4):
            tc = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=0,
                                           total_steps=10),
                             q_chunk=64, microbatches=k)
            step, *_ = make_train_step(cfg, mesh, tc, shape, fsdp=False,
                                       donate=False)
            state = init_state(jax.random.PRNGKey(3), cfg, tc)
            s2, m = step(state, batch)
            outs.append(s2["params"])
    a = jax.tree.leaves(outs[0])
    b = jax.tree.leaves(outs[1])
    for x, y in zip(a, b):
        assert jnp.allclose(x, y, rtol=2e-3, atol=2e-4), "microbatch mismatch"


@pytest.mark.slow
def test_single_vs_two_machines_subprocess():
    """Run the paper-tables worker at 1 and 2 virtual devices; sufficient-
    stats algorithms must produce identical accuracy (paper Tables 2-6)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = {}
    for dev in (1, 2):
        res = subprocess.run(
            [sys.executable, os.path.join(ROOT, "benchmarks", "paper_tables.py"),
             "--n", "4000", "--n-test", "800", "--devices", str(dev),
             "--algos", "nb,dt", "--transforms", "none"],
            env=env, capture_output=True, text=True, timeout=1200)
        assert res.returncode == 0, res.stderr[-2000:]
        rows = [l for l in res.stdout.splitlines() if re.match(r"^\d", l)]
        out[dev] = {l.split(",")[1]: float(l.split(",")[4]) for l in rows}
    for algo in ("nb", "dt"):
        assert abs(out[1][algo] - out[2][algo]) < 0.01, (algo, out)


def test_serve_driver_runs():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "stablelm-1.6b",
         "--smoke", "--batch", "2", "--prompt-len", "32", "--gen", "4"],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "decode:" in res.stdout
