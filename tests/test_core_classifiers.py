"""Classifier correctness: separable-data sanity + sleep-data accuracy bands
+ single-vs-distributed equivalence (the paper's central claim: more machines,
same model)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALGORITHMS, PCA, SVD, metrics
from repro.core.estimator import DistContext
from repro.sharding.axes import make_test_mesh


def _blobs(key, n=1200, f=10, k=3, sep=4.0):
    ks = jax.random.split(key, 2)
    y = jax.random.randint(ks[0], (n,), 0, k)
    centers = sep * jax.random.normal(jax.random.PRNGKey(7), (k, f))
    X = centers[y] + jax.random.normal(ks[1], (n, f))
    return X, y


@pytest.mark.parametrize("name", ["nb", "lr", "svm", "dt", "rf", "gbt", "ada"])
def test_separable_blobs(rng, name):
    X, y = _blobs(rng)
    algo = ALGORITHMS[name](n_classes=3)
    params = algo.fit(X, y, DistContext(), key=rng)
    acc = metrics.evaluate(y, algo.predict(params, X), 3)["accuracy"]
    assert acc > 0.9, f"{name}: {acc}"


@pytest.mark.parametrize("name,floor", [
    ("nb", 0.45), ("lr", 0.75), ("dt", 0.70), ("rf", 0.72),
    ("gbt", 0.75), ("svm", 0.72), ("ada", 0.55),
])
def test_sleep_accuracy_band(sleep_dataset, rng, name, floor):
    """Paper-regime accuracy on the synthetic sleep task (ceiling ~0.84
    from label noise)."""
    ds = sleep_dataset
    algo = ALGORITHMS[name](n_classes=6)
    params = algo.fit(ds["X_train"], ds["y_train"], DistContext(), key=rng)
    rep = metrics.evaluate(ds["y_test"], algo.predict(params, ds["X_test"]), 6)
    assert floor < rep["accuracy"] <= 0.92, (name, rep["accuracy"])


@pytest.mark.parametrize("name", ["nb", "dt", "gbt"])
def test_single_vs_distributed_equivalence(sleep_dataset, name):
    """2 virtual shards on 1 device: sufficient-stats algorithms must give
    bitwise-comparable models to the single-machine run (paper Tables 2-6
    show identical A/P/R across cluster sizes)."""
    ds = sleep_dataset
    n = (ds["X_train"].shape[0] // 2) * 2
    X, y = ds["X_train"][:n], ds["y_train"][:n]
    single = ALGORITHMS[name](n_classes=6)
    p1 = single.fit(X, y, DistContext(), key=jax.random.PRNGKey(5))

    mesh = make_test_mesh(1, 1)  # 1-device mesh exercising the shard_map path
    ctx = DistContext(mesh=mesh)
    p2 = single.fit(X, y, ctx, key=jax.random.PRNGKey(5))
    pred1 = single.predict(p1, ds["X_test"])
    pred2 = single.predict(p2, ds["X_test"])
    agree = float((pred1 == pred2).mean())
    assert agree > 0.995, agree


def test_gbt_mllib2018_pathology(sleep_dataset):
    """The paper's GBT accuracy (0.214) came from running a binary-only GBT
    on 6 classes; our faithful mode must reproduce the collapse."""
    ds = sleep_dataset
    algo = ALGORITHMS["gbt"](n_classes=6)
    algo.mode = "mllib2018"
    p = algo.fit(ds["X_train"], ds["y_train"], DistContext())
    pred = algo.predict(p, ds["X_test"])
    assert int(jnp.unique(pred).size) <= 2          # only two classes ever
    acc = metrics.evaluate(ds["y_test"], pred, 6)["accuracy"]
    fixed = ALGORITHMS["gbt"](n_classes=6)
    pf = fixed.fit(ds["X_train"], ds["y_train"], DistContext())
    accf = metrics.evaluate(ds["y_test"], fixed.predict(pf, ds["X_test"]),
                            6)["accuracy"]
    assert acc < 0.5 < accf


def test_pca_reconstruction(rng):
    X = jax.random.normal(rng, (2000, 20)) @ jax.random.normal(
        jax.random.PRNGKey(1), (20, 40))
    pca = PCA(20)
    p, Xt = pca.fit_transform(X)
    assert Xt.shape == (2000, 20)
    # 20 latent dims -> 20 components capture everything
    tot = jnp.var(X - X.mean(0), axis=0).sum()
    assert float(p["explained"].sum()) / float(tot) > 0.99


def test_svd_matches_dense_svd(rng):
    X = jax.random.normal(rng, (1024, 30))
    svd = SVD(5, power_iters=4)
    p = svd.fit(X)
    _, s_np, _ = np.linalg.svd(np.asarray(X), full_matrices=False)
    np.testing.assert_allclose(p["singular_values"], s_np[:5], rtol=2e-2)


def test_metrics_confusion(rng):
    y = jnp.array([0, 0, 1, 1, 2, 2])
    pred = jnp.array([0, 1, 1, 1, 2, 0])
    cm = metrics.confusion_matrix(y, pred, 3)
    np.testing.assert_allclose(cm, [[1, 1, 0], [0, 2, 0], [1, 0, 1]])
    rep = metrics.classification_report(cm)
    np.testing.assert_allclose(rep["accuracy"], 4 / 6, rtol=1e-6)
