"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,t", [(8, 3000), (16, 1000), (24, 257), (8, 128)])
def test_band_stats_matches_ref(rng, n, t):
    x = jax.random.normal(rng, (n, 5, t)) * 40 + 3
    xs = jnp.sort(x, axis=-1)
    got = ops.band_stats(xs, force="interpret")
    want = ref.band_stats_ref(xs)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_band_stats_dtypes(rng, dtype):
    x = jnp.sort(jax.random.normal(rng, (8, 5, 512)).astype(dtype), -1)
    got = ops.band_stats(x.astype(jnp.float32), force="interpret")
    assert got.shape == (8, 5, 15)
    assert not bool(jnp.isnan(got).any())


@pytest.mark.parametrize("n,f", [(512, 75), (1024, 128), (600, 33), (2048, 256)])
def test_gram_matches_ref(rng, n, f):
    X = jax.random.normal(rng, (n, f))
    got = ops.gram(X, force="interpret")
    want = ref.gram_ref(X)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)


def test_gram_symmetric(rng):
    X = jax.random.normal(rng, (512, 75))
    g = ops.gram(X, force="interpret")
    np.testing.assert_allclose(g, g.T, rtol=1e-6, atol=1e-4)


@pytest.mark.parametrize("n,s,b,c", [(2048, 8, 32, 6), (512, 32, 16, 3),
                                     (1000, 4, 8, 1)])
def test_hist_matches_ref(rng, n, s, b, c):
    k1, k2, k3 = jax.random.split(rng, 3)
    bins = jax.random.randint(k1, (n,), 0, b)
    node = jax.random.randint(k2, (n,), 0, s)
    stat = jax.random.normal(k3, (n, c))
    got = ops.hist(bins, node, stat, s, b, force="interpret")
    want = ref.hist_ref(bins, node, stat, s, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_hist_total_mass(rng):
    bins = jax.random.randint(rng, (2048,), 0, 32)
    node = jax.random.randint(rng, (2048,), 0, 8)
    stat = jnp.ones((2048, 2))
    got = ops.hist(bins, node, stat, 8, 32, force="interpret")
    np.testing.assert_allclose(got.sum(), 2048 * 2, rtol=1e-6)


@pytest.mark.parametrize("s,d,h,window", [
    (256, 64, 4, 0), (256, 64, 4, 64), (384, 128, 2, 128), (128, 32, 8, 32),
])
def test_swa_matches_ref(rng, s, d, h, window):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, s, h, d)) * 0.3
    k = jax.random.normal(ks[1], (2, s, h, d)) * 0.3
    v = jax.random.normal(ks[2], (2, s, h, d))
    got = ops.swa_attention(q, k, v, window=window, force="interpret")
    want = ref.swa_attention_ref(q, k, v, window)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_swa_bf16(rng):
    q = (jax.random.normal(rng, (1, 128, 2, 128)) * 0.3).astype(jnp.bfloat16)
    got = ops.swa_attention(q, q, q, window=64, force="interpret")
    want = ref.swa_attention_ref(q, q, q, 64)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), rtol=2e-2, atol=2e-2)


def test_swa_window_equals_full_when_large(rng):
    q = jax.random.normal(rng, (1, 128, 2, 64)) * 0.3
    a = ops.swa_attention(q, q, q, window=0, force="interpret")
    b = ops.swa_attention(q, q, q, window=4096, force="interpret")
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
