"""Substrate tests: optimizer, checkpoint roundtrip, data pipeline, specs,
HLO parser, configs."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, SHAPES_BY_NAME
from repro.launch.hloparse import loop_multipliers, shape_bytes
from repro.sharding import specs as specs_lib
from repro.sharding.axes import SINGLE_POD, make_test_mesh
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig, adamw_init, adamw_update, lr_at


def test_configs_match_assignment():
    spec = {
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, h, kv, ff, v), arch


def test_param_counts_sane():
    assert abs(get_config("jamba-1.5-large-398b").param_count() - 398e9) < 20e9
    assert abs(get_config("qwen3-moe-235b-a22b").param_count() - 235e9) < 12e9
    a = get_config("qwen3-moe-235b-a22b")
    assert abs(a.active_param_count() - 22e9) < 3e9
    assert abs(get_config("qwen2-moe-a2.7b").active_param_count() - 2.7e9) < 1e9


def test_moe_experts_divide_production_tp():
    from repro.models.moe import padded_experts
    for arch in ARCH_IDS:
        c = get_config(arch)
        if c.n_experts:
            assert padded_experts(c.n_experts) % 16 == 0, arch


def test_lr_schedule():
    oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(oc, jnp.asarray(0))) == 0.0
    assert abs(float(lr_at(oc, jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr_at(oc, jnp.asarray(100))) < 1e-8


def test_adamw_reduces_quadratic():
    oc = OptConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params, oc)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(grads, opt, params, oc)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_checkpoint_roundtrip(tmp_path, rng):
    state = {"params": {"a": jax.random.normal(rng, (4, 8)),
                        "nested": {"b": jnp.arange(5, dtype=jnp.int32)}},
             "opt": {"step": jnp.int32(7)}}
    ckpt.save(str(tmp_path / "c"), state, step=7)
    struct = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    got = ckpt.restore(str(tmp_path / "c"), struct)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_allclose(a, b)


def test_checkpoint_latest_step(tmp_path):
    for s in (10, 20, 5):
        os.makedirs(tmp_path / f"step_{s}")
    assert ckpt.latest_step(str(tmp_path)) == 20


@pytest.mark.parametrize("arch", ["llama3.2-3b", "qwen3-moe-235b-a22b",
                                  "jamba-1.5-large-398b", "xlstm-1.3b",
                                  "whisper-medium"])
def test_param_specs_cover_tree(arch, rng):
    """Spec tree must structurally match the param tree (every leaf gets a
    PartitionSpec of matching rank)."""
    cfg = get_smoke_config(arch)
    mesh = make_test_mesh()
    from repro.models import init_params
    params = init_params(rng, cfg)
    specs = specs_lib.build(cfg, mesh, SINGLE_POD, fsdp=True).param_specs()
    pl = jax.tree_util.tree_flatten_with_path(params)[0]
    sl = dict(jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: type(x).__name__ == "PartitionSpec")[0])
    assert len(pl) == len(sl)
    for path, leaf in pl:
        spec = sl[path]
        assert len(tuple(spec)) <= leaf.ndim, (path, spec, leaf.shape)


def test_cache_specs_cover_layout():
    from repro.models.kvcache import cache_layout
    for arch in ("llama3.2-3b", "jamba-1.5-large-398b", "xlstm-1.3b",
                 "whisper-medium"):
        cfg = get_config(arch)
        for sh in ("decode_32k", "long_500k"):
            shape = SHAPES_BY_NAME[sh]
            mesh = make_test_mesh()
            cs = specs_lib.build(cfg, mesh, SINGLE_POD, False).cache_specs(shape)
            lay = cache_layout(cfg, shape.global_batch, shape.seq_len)
            assert set(cs) == set(lay)
            for pj in lay:
                assert set(cs[pj]) == set(lay[pj]), (arch, sh, pj)


def test_hlo_shape_bytes():
    assert shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert shape_bytes("(f32[4,4]{1,0}, s32[2]{0})") == 64 + 8
    assert shape_bytes("pred[10]{0}") == 10


def test_loop_multipliers_nested():
    hlo = """
ENTRY %main.1 (p0: f32[2]) -> f32[2] {
  %w1 = (s32[], f32[2]) while(%t), condition=%cond1, body=%body1, backend_config={"known_trip_count":{"n":"5"}}
}
%body1 (p: (s32[], f32[2])) -> (s32[], f32[2]) {
  %w2 = (s32[], f32[2]) while(%t2), condition=%cond2, body=%body2, backend_config={"known_trip_count":{"n":"3"}}
}
%body2 (p: (s32[], f32[2])) -> (s32[], f32[2]) {
  %x = f32[2] add(%a, %b)
}
"""
    m = loop_multipliers(hlo)
    assert m.get("body1") == 5
    assert m.get("body2") == 15


def test_token_stream_deterministic():
    from repro.data.pipeline import token_stream
    cfg = get_smoke_config("llama3.2-3b")
    a = next(token_stream(cfg, 2, 16, seed=1))
    b = next(token_stream(cfg, 2, 16, seed=1))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_features_shape_and_finite():
    from repro.data.features import extract_features
    from repro.data.synthetic_eeg import synth_epochs
    X, y = synth_epochs(jax.random.PRNGKey(0), 32)
    F = extract_features(X, use_kernel=False)
    assert F.shape == (32, 75)
    assert bool(jnp.isfinite(F).all())
    assert set(np.asarray(jnp.unique(y)).tolist()) <= set(range(6))


def test_stage_spectra_distinguishable():
    """Delta power must dominate for S4, beta/alpha for W — the Table-1
    conditioning is actually in the signal."""
    from repro.data.features import band_split
    from repro.data.synthetic_eeg import synth_epochs
    key = jax.random.PRNGKey(1)
    X, y = synth_epochs(key, 512)
    bands = band_split(X)                       # (n,5,T)
    power = (bands ** 2).mean(-1)
    w_mask = y == 0
    s4_mask = y == 4
    if bool(w_mask.any()) and bool(s4_mask.any()):
        delta_ratio_s4 = float(power[s4_mask, 0].mean() / power[s4_mask].sum(-1).mean())
        delta_ratio_w = float(power[w_mask, 0].mean() / power[w_mask].sum(-1).mean())
        assert delta_ratio_s4 > delta_ratio_w
