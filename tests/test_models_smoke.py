"""Per-architecture smoke tests (deliverable f): REDUCED variant of each
family — one forward + one train step + one decode step on CPU, asserting
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.shapes import InputShape
from repro.models import (Context, decode_step, forward, init_cache,
                          init_params, prefill)
from repro.sharding.axes import SINGLE_POD, make_test_mesh
from repro.train.loop import TrainConfig, init_state, make_train_step
from repro.train.optimizer import OptConfig

B, S = 2, 64


def _inputs(cfg, rng):
    tok_len = S - (cfg.n_patches or 0)
    tokens = jax.random.randint(rng, (B, tok_len), 0, cfg.vocab_size)
    frontend = None
    if cfg.n_patches:
        frontend = 0.1 * jax.random.normal(rng, (B, cfg.n_patches, cfg.d_model))
    elif cfg.is_enc_dec:
        frontend = 0.1 * jax.random.normal(rng, (B, cfg.n_frames, cfg.d_model))
    return tokens, frontend


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_decode(arch, rng):
    cfg = get_smoke_config(arch)
    mesh = make_test_mesh()
    params = init_params(rng, cfg)
    tokens, frontend = _inputs(cfg, rng)
    ctx = Context(mesh=mesh, axes=SINGLE_POD, batch_sharded=False,
                  fsdp=False, q_chunk=32)
    with jax.set_mesh(mesh):
        h, _, aux = forward(params, cfg, tokens, ctx, frontend=frontend)
        assert h.shape == (B, S, cfg.d_model)
        assert not bool(jnp.isnan(h).any())
        logits, cache = prefill(params, cfg, tokens, ctx, frontend=frontend)
        assert logits.shape[-1] >= cfg.vocab_size
        assert not bool(jnp.isnan(logits).any())
        lg, cache = decode_step(params, cfg, tokens[:, -1:], cache,
                                jnp.int32(S), ctx)
        assert lg.shape[:2] == (B, 1)
        assert not bool(jnp.isnan(lg).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch, rng):
    cfg = get_smoke_config(arch)
    mesh = make_test_mesh()
    shape = InputShape("t", S, B, "train")
    tc = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=1, total_steps=4),
                     q_chunk=32, microbatches=1)
    with jax.set_mesh(mesh):
        step, *_ = make_train_step(cfg, mesh, tc, shape, fsdp=False,
                                   donate=False)
        state = init_state(rng, cfg, tc)
        tokens, frontend = _inputs(cfg, rng)
        batch = {"tokens": tokens,
                 "labels": jnp.mod(tokens + 1, cfg.vocab_size)}
        if frontend is not None:
            batch["frontend"] = frontend
        state2, m = step(state, batch)
        assert not bool(jnp.isnan(m["loss"]))
        assert float(m["loss"]) > 0
        # params actually moved
        d0 = jax.tree.leaves(state["params"])[0]
        d1 = jax.tree.leaves(state2["params"])[0]
        assert not jnp.allclose(d0, d1)
