"""Cross-validation / model-selection utilities."""
import numpy as np

from repro.core.crossval import cross_validate, grid_search, kfold_indices
from repro.core.logistic_regression import LogisticRegression
from repro.core.naive_bayes import NaiveBayes


def test_kfold_partition():
    folds = list(kfold_indices(100, 5, seed=1))
    assert len(folds) == 5
    all_test = np.concatenate([te for _tr, te in folds])
    assert sorted(all_test.tolist()) == list(range(100))
    for tr, te in folds:
        assert set(tr).isdisjoint(set(te))
        assert len(tr) + len(te) == 100


def test_cross_validate_blobs(rng=None):
    import jax
    key = jax.random.PRNGKey(0)
    import jax.numpy as jnp
    y = jax.random.randint(key, (600,), 0, 3)
    centers = 5.0 * jax.random.normal(jax.random.PRNGKey(1), (3, 8))
    X = centers[y] + jax.random.normal(jax.random.PRNGKey(2), (600, 8))
    res = cross_validate(lambda: NaiveBayes(3), X, y, n_classes=3, k=4)
    assert res["acc_mean"] > 0.9
    assert res["folds"] == 4


def test_grid_search_picks_reasonable():
    import jax
    import jax.numpy as jnp
    key = jax.random.PRNGKey(0)
    y = jax.random.randint(key, (400,), 0, 2)
    centers = 3.0 * jax.random.normal(jax.random.PRNGKey(1), (2, 6))
    X = centers[y] + jax.random.normal(jax.random.PRNGKey(2), (400, 6))
    out = grid_search(LogisticRegression, {"iters": [5, 60]}, X, y,
                      n_classes=2, k=3)
    assert out["best"]["acc_mean"] >= max(r["acc_mean"] for r in out["all"]) - 1e-9
