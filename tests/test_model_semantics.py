"""Semantic invariants of the model zoo:

* decode path == full forward (teacher-forced next-token logits) for every
  block family — validates the KV cache, circular SWA buffer, and the
  recurrent state updates against the parallel (chunked) forms;
* chunked attention == single-chunk attention;
* mLSTM chunked-parallel == step-by-step recurrence;
* Mamba chunked scan == step-by-step recurrence.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Context, decode_step, forward, prefill, unembed
from repro.models import ssm as ssm_lib
from repro.models.attention import attention
from repro.sharding.axes import SINGLE_POD, make_test_mesh

B, S = 2, 32


@pytest.mark.parametrize("arch", [
    "stablelm-1.6b",            # dense, layernorm, MHA
    "llama3.2-3b",              # GQA + head padding + tied embeddings
    "codeqwen1.5-7b",           # dense, high rope theta
    "internlm2-20b",            # dense GQA
    "qwen2-moe-a2.7b",          # MoE + shared experts
    "qwen3-moe-235b-a22b",      # 128-expert top-8 MoE
    "xlstm-1.3b",               # mLSTM + sLSTM
    "jamba-1.5-large-398b",     # mamba + attn + MoE hybrid
    "whisper-medium",           # enc-dec + cross-attn + learned pos
    "llava-next-mistral-7b",    # VLM prefix tokens
])
def test_decode_matches_forward(arch, rng):
    """prefill(S-1 tokens) + decode(token S-1) == forward(S tokens) last logits."""
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        # capacity drops are legitimate train/prefill-vs-decode divergence
        # (decode never drops); disable them to verify the exact math
        cfg = cfg.replace(capacity_factor=64.0)
    mesh = make_test_mesh()
    from repro.models import init_params
    params = init_params(rng, cfg)
    tok_len = S - (cfg.n_patches or 0)
    tokens = jax.random.randint(rng, (B, tok_len), 0, cfg.vocab_size)
    frontend = None
    if cfg.n_patches:
        frontend = 0.1 * jax.random.normal(rng, (B, cfg.n_patches, cfg.d_model))
    elif cfg.is_enc_dec:
        frontend = 0.1 * jax.random.normal(rng, (B, cfg.n_frames, cfg.d_model))
    ctx = Context(mesh=mesh, axes=SINGLE_POD, batch_sharded=False,
                  fsdp=False, q_chunk=16)
    with jax.set_mesh(mesh):
        h, _, _ = forward(params, cfg, tokens, ctx, frontend=frontend)
        want = unembed(params, cfg, h[:, -1:])

        logits_pf, cache = prefill(params, cfg, tokens[:, :-1], ctx,
                                   frontend=frontend)
        from repro.models.kvcache import grow_cache
        full_len = tok_len + (cfg.n_patches or 0)
        cache = grow_cache(cache, cfg, B, full_len)
        got, _ = decode_step(params, cfg, tokens[:, -1:], cache,
                             jnp.int32(full_len - 1), ctx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_chunked_attention_matches_single(rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 64, 2, 3, 16)) * 0.5
    k = jax.random.normal(ks[1], (2, 64, 2, 16)) * 0.5
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    a = attention(q, k, v, causal=True, q_chunk=64)
    b = attention(q, k, v, causal=True, q_chunk=16)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_chunked_attention_window(rng):
    q = jax.random.normal(rng, (1, 64, 1, 2, 16)) * 0.5
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 64, 1, 16)) * 0.5
    v = jax.random.normal(jax.random.fold_in(rng, 2), (1, 64, 1, 16))
    a = attention(q, k, v, causal=True, window=16, q_chunk=64)
    b = attention(q, k, v, causal=True, window=16, q_chunk=8)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def _mini_cfg(kind):
    base = get_smoke_config("xlstm-1.3b" if kind != "mamba"
                            else "jamba-1.5-large-398b")
    return base


def test_mlstm_chunked_equals_recurrent(rng):
    cfg = _mini_cfg("mlstm").replace(d_model=64, n_heads=2)
    p = ssm_lib.init_mlstm(rng, cfg, cfg.d_model)
    x = 0.5 * jax.random.normal(rng, (2, 24, cfg.d_model))
    y_par, _ = ssm_lib.mlstm_block(x, p, cfg, chunk=8)
    # step-by-step decode
    di = cfg.ssm_expand * cfg.d_model
    nh = cfg.n_heads
    hd = di // nh
    st = (jnp.zeros((2, nh, hd, hd)), jnp.zeros((2, nh, hd)),
          jnp.full((2, nh), -1e30), jnp.zeros((2, nh)))
    outs = []
    for t in range(24):
        o, st = ssm_lib.mlstm_decode(x[:, t:t + 1], p, cfg, st)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)


def test_mamba_chunked_equals_recurrent(rng):
    cfg = _mini_cfg("mamba").replace(d_model=48)
    p = ssm_lib.init_mamba(rng, cfg, cfg.d_model)
    x = 0.5 * jax.random.normal(rng, (2, 16, cfg.d_model))
    y_par, _ = ssm_lib.mamba_block(x, p, cfg, chunk=4)
    state = {"h": jnp.zeros((2, cfg.ssm_expand * cfg.d_model, cfg.ssm_d_state)),
             "conv": jnp.zeros((2, cfg.ssm_d_conv - 1,
                                cfg.ssm_expand * cfg.d_model))}
    outs = []
    for t in range(16):
        o, state = ssm_lib.mamba_decode(x[:, t:t + 1], p, cfg, state)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)


def test_swa_train_equals_full_when_window_ge_seq(rng):
    """window >= S: SWA must equal full attention (the long_500k dense
    variant degenerates correctly)."""
    cfg = get_smoke_config("llama3.2-3b")
    from repro.models import init_params
    params = init_params(rng, cfg)
    tokens = jax.random.randint(rng, (1, S), 0, cfg.vocab_size)
    mesh = make_test_mesh()
    with jax.set_mesh(mesh):
        c0 = Context(mesh=mesh, axes=SINGLE_POD, batch_sharded=False,
                     q_chunk=16, window=0)
        c1 = Context(mesh=mesh, axes=SINGLE_POD, batch_sharded=False,
                     q_chunk=16, window=S + 5)
        h0, _, _ = forward(params, cfg, tokens, c0)
        h1, _, _ = forward(params, cfg, tokens, c1)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1),
                               rtol=1e-5, atol=1e-5)
