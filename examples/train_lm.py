"""Train a ~100M-param llama-family model for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses the production training stack (sharded step, AdamW, checkpointing,
token pipeline) on a 1x1 mesh; the same code lowers to the 16x16 production
mesh in launch/dryrun.py.
"""
import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=512)
    args = ap.parse_args()
    # ~100M: d_model=768, 12 layers of the llama3.2 family (reduced variant
    # overridden upward), vocab 512 -> ~86M trunk + embeddings
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "llama3.2-3b", "--smoke",
        "--d-model", "768", "--n-layers", "12",
        "--steps", str(args.steps), "--batch", str(args.batch),
        "--seq", str(args.seq), "--ckpt-dir", "/tmp/repro_lm_ckpt",
        "--ckpt-every", "100", "--log-every", "10",
    ]
    print(" ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env={"PYTHONPATH": "src",
                                               **__import__("os").environ}))


if __name__ == "__main__":
    main()
