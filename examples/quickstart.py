"""Quickstart: the paper's pipeline end-to-end in ~2 minutes on CPU.

Synthesize sleep-EDF-like EEG (Table 1 spectra) -> 75 features -> train the
paper's classifiers -> report accuracy / precision / recall (paper eqs 1-3).

    PYTHONPATH=src python examples/quickstart.py [--n 8000]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.core import ALGORITHMS, PCA, metrics
from repro.core.estimator import DistContext
from repro.data.pipeline import make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--algos", default="nb,lr,dt")
    args = ap.parse_args()

    print(f"synthesizing {args.n} EEG epochs + extracting 75 features ...")
    t0 = time.time()
    ds = make_dataset(args.n, args.n // 5, chunk=4000)
    print(f"  done in {time.time()-t0:.1f}s")

    ctx = DistContext()                 # single machine (paper's baseline)
    for name in args.algos.split(","):
        algo = ALGORITHMS[name](n_classes=6)
        t0 = time.time()
        params = algo.fit(ds["X_train"], ds["y_train"], ctx,
                          key=jax.random.PRNGKey(0))
        rep = metrics.evaluate(ds["y_test"], algo.predict(params, ds["X_test"]),
                               6, ctx)
        print(f"  {name:4s} A={rep['accuracy']:.3f} P={rep['precision']:.3f} "
              f"R={rep['recall']:.3f}  ({time.time()-t0:.1f}s)")

    # the paper's PCA variant
    pca = PCA(16)
    p, Xt = pca.fit_transform(ds["X_train"], ctx)
    algo = ALGORITHMS["lr"](n_classes=6)
    params = algo.fit(Xt, ds["y_train"], ctx)
    rep = metrics.evaluate(ds["y_test"],
                           algo.predict(params, pca.transform(p, ds["X_test"])),
                           6, ctx)
    print(f"  lr+pca A={rep['accuracy']:.3f} "
          f"(explained var: {[round(float(v),1) for v in p['explained'][:4]]}...)")


if __name__ == "__main__":
    main()
