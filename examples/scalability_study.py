"""The paper's experiment: single machine vs "more than one machine".

    PYTHONPATH=src python examples/scalability_study.py [--n 20000] [--devices 8]

Runs each classifier x {C, PCA, SVD} on one device, then re-runs the same
workload data-parallel over N virtual host devices (a subprocess sets
--xla_force_host_platform_device_count, so the parent process keeps its
1-device view).  Wall times on virtual devices of ONE physical CPU are
structural, not a hardware speedup claim — the distributed path's collective
schedule is what's validated (EXPERIMENTS.md §Paper-tables).
"""
import argparse
import json
import os
import subprocess
import sys

WORKER = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                      "paper_tables.py")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--algos", default="nb,lr,dt,rf,gbt")
    args = ap.parse_args()
    env = dict(os.environ, PYTHONPATH="src")
    for ndev in (1, args.devices):
        print(f"\n=== {'single machine' if ndev == 1 else f'{ndev} machines (virtual)'} ===")
        cmd = [sys.executable, WORKER, "--n", str(args.n),
               "--devices", str(ndev), "--algos", args.algos,
               "--transforms", "none,pca,svd"]
        subprocess.check_call(cmd, env=env)


if __name__ == "__main__":
    main()
