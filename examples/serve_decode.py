"""Serve a small model with batched requests: prefill + token-by-token decode.

    PYTHONPATH=src python examples/serve_decode.py [--arch qwen2-moe-a2.7b]

Exercises the production serving split (prefill program emits the KV cache;
decode program appends one token into the circular cache per step) on the
reduced config — including the MoE expert-parallel path when the arch is MoE.
"""
import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cmd = [
        sys.executable, "-m", "repro.launch.serve",
        "--arch", args.arch, "--smoke",
        "--batch", str(args.batch), "--prompt-len", str(args.prompt_len),
        "--gen", str(args.gen),
    ]
    print(" ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env={"PYTHONPATH": "src",
                                               **__import__("os").environ}))


if __name__ == "__main__":
    main()
