"""Ablation studies the paper lists as future work (§4):

1. feature-group ablation — which of the 15 statistics matter;
2. band ablation — which R&K frequency bands carry the signal;
3. data-scaling curve — accuracy vs training-set size (the paper claims
   500M examples; this shows where the curve flattens on the synthetic task);
4. per-stage confusion — which stages are confusable (W/REM, S3/S4).

    PYTHONPATH=src python examples/ablations.py [--n 16000]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import ALGORITHMS, metrics
from repro.core.estimator import DistContext
from repro.data.pipeline import make_dataset
from repro.data.synthetic_eeg import STAGE_NAMES

STATS = ("mean", "hmean", "trimmed_mean", "energy", "entropy", "min",
         "median", "max", "std", "skew", "q25", "q75", "iqr", "abs_skew",
         "kurtosis")
BANDS = ("delta", "theta", "alpha", "spindle", "beta")


def acc_with(ds, cols, ctx):
    algo = ALGORITHMS["lr"](n_classes=6)
    p = algo.fit(ds["X_train"][:, cols], ds["y_train"], ctx)
    rep = metrics.evaluate(ds["y_test"],
                           algo.predict(p, ds["X_test"][:, cols]), 6, ctx)
    return rep["accuracy"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16000)
    args = ap.parse_args()
    ctx = DistContext()
    ds = make_dataset(args.n, args.n // 4, chunk=4000)
    full = acc_with(ds, np.arange(75), ctx)
    print(f"full 75-feature LR accuracy: {full:.3f}\n")

    print("== band ablation (LR, drop one band = its 15 features) ==")
    for b, band in enumerate(BANDS):
        cols = np.asarray([i for i in range(75) if i // 15 != b])
        print(f"  -{band:8s}: {acc_with(ds, cols, ctx):.3f} "
              f"(delta {acc_with(ds, cols, ctx)-full:+.3f})")
    print("\n== single-band (only that band's 15 features) ==")
    for b, band in enumerate(BANDS):
        cols = np.arange(b * 15, (b + 1) * 15)
        print(f"  {band:8s}: {acc_with(ds, cols, ctx):.3f}")

    print("\n== statistic-group ablation (drop one stat across all bands) ==")
    for s, stat in enumerate(STATS):
        cols = np.asarray([i for i in range(75) if i % 15 != s])
        print(f"  -{stat:12s}: {acc_with(ds, cols, ctx):.3f}")

    print("\n== data-scaling curve (LR) ==")
    for frac in (0.05, 0.1, 0.25, 0.5, 1.0):
        n = int(len(ds["X_train"]) * frac)
        sub = dict(ds, X_train=ds["X_train"][:n], y_train=ds["y_train"][:n])
        print(f"  n={n:6d}: {acc_with(sub, np.arange(75), ctx):.3f}")

    print("\n== per-stage confusion (LR, full features) ==")
    algo = ALGORITHMS["lr"](n_classes=6)
    p = algo.fit(ds["X_train"], ds["y_train"], ctx)
    cm = np.asarray(metrics.confusion_matrix(
        ds["y_test"], algo.predict(p, ds["X_test"]), 6))
    cmn = cm / np.maximum(cm.sum(1, keepdims=True), 1)
    print("        " + " ".join(f"{n:>6s}" for n in STAGE_NAMES))
    for i, n in enumerate(STAGE_NAMES):
        print(f"  {n:>5s} " + " ".join(f"{v:6.2f}" for v in cmn[i]))


if __name__ == "__main__":
    main()
