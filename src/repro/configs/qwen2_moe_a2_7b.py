"""Qwen2-MoE A2.7B — 4 shared + 60 routed experts top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,          # GQA kv=16 (full MHA)
    head_dim=128,
    d_ff=1408,              # per-expert intermediate
    vocab_size=151_936,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=1_000_000.0,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    d_expert=1408,
)
