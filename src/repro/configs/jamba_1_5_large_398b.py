"""Jamba-1.5-Large 398B — hybrid Mamba+attention 1:7 interleave with MoE.
[arXiv:2403.19887]

One attention layer per 8 (l % 8 == attn_offset), Mamba elsewhere; MoE FFN on
every other layer (16 experts, top-2), dense FFN otherwise.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    source="arXiv:2403.19887",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,           # GQA kv=8
    head_dim=128,
    d_ff=24576,
    vocab_size=65_536,
    norm="rmsnorm",
    activation="swiglu",
    # hybrid: attention on layers l % 8 == 4, Mamba on the other 7
    attn_every=8,
    attn_offset=4,
    ssm_d_state=16,
    ssm_d_conv=4,
    ssm_expand=2,
    # MoE: 16 experts top-2 on every other layer
    n_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
)
