"""LLaVA-NeXT (mistral-7B backbone) — VLM with anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

The vision tower (CLIP ViT-L/336 + 2-layer MLP projector) is a STUB per the
carve-out: ``input_specs()`` supplies pre-projected patch embeddings of shape
(batch, n_patches, d_model).  anyres tiling = base image + 4 tiles, 576
patches each -> 2880 patch embeddings per image.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,           # GQA kv=8 (mistral)
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=1_000_000.0,
    n_patches=2880,         # anyres: (1 base + 4 tiles) x 576
)
