"""Config registry: ``get_config(arch_id)`` / ``ARCH_IDS`` / smoke variants."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig, SleepConfig, reduce_config
from repro.configs.shapes import (
    SHAPES,
    SHAPES_BY_NAME,
    InputShape,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
)

_MODULES: Dict[str, str] = {
    "stablelm-1.6b": "stablelm_1_6b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "llama3.2-3b": "llama3_2_3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "whisper-medium": "whisper_medium",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "internlm2-20b": "internlm2_20b",
    "xlstm-1.3b": "xlstm_1_3b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return reduce_config(get_config(arch_id))


__all__ = [
    "ModelConfig", "SleepConfig", "reduce_config", "get_config",
    "get_smoke_config", "ARCH_IDS", "SHAPES", "SHAPES_BY_NAME", "InputShape",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]
