"""Llama-3.2-3B — small llama3 dense GQA decoder.  [hf:meta-llama/Llama-3.2-1B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    arch_type="dense",
    source="hf:meta-llama/Llama-3.2-1B",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,           # GQA kv=8
    head_dim=128,
    d_ff=8192,
    vocab_size=128_256,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=True,
)
