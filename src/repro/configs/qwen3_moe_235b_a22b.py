"""Qwen3-MoE 235B-A22B — 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,           # GQA kv=4
    head_dim=128,
    d_ff=1536,              # per-expert intermediate
    vocab_size=151_936,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    d_expert=1536,
)
