"""StableLM-2 1.6B — dense MHA decoder.  [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,          # GQA kv=32 (full MHA)
    d_ff=5632,
    vocab_size=100_352,
    norm="layernorm",       # stablelm-2 uses LayerNorm
    activation="swiglu",
    rope_theta=10_000.0,
)
