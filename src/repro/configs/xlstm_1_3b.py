"""xLSTM-1.3B — sLSTM + mLSTM recurrent blocks (attention-free).
[arXiv:2405.04517]

48 blocks: mLSTM (matrix memory, parallelizable via associative scan) with an
sLSTM (scalar memory, sequential) block every 8th position (l % 8 == 1),
mirroring the paper's sparse sLSTM placement.  d_ff=0: xLSTM blocks carry
their own up/down projections (expand=2); there is no separate FFN.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    source="arXiv:2405.04517",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,           # mLSTM memory heads
    d_ff=0,                 # no separate FFN (per assignment)
    vocab_size=50_304,
    norm="layernorm",
    activation="gelu",
    pos_embedding="none",   # recurrence encodes position
    slstm_every=8,
    ssm_expand=2,
)
