"""Model / workload configuration system.

Every assigned architecture is expressed as a :class:`ModelConfig`; the paper's
own workload (distributed sleep-stage classification) is a :class:`SleepConfig`.
Configs are plain frozen dataclasses — hashable, printable, and cheap — and the
model code consumes nothing else.

Block kinds
-----------
The transformer zoo assembles a stack of homogeneous *block groups* (so the
runtime can ``lax.scan`` over each group's stacked parameters).  A block kind is
one of:

  ``attn``    pre-norm GQA attention + MLP (dense) or MoE
  ``mamba``   Mamba selective-SSM block (+ MLP/MoE per config)
  ``mlstm``   xLSTM matrix-memory block
  ``slstm``   xLSTM scalar-memory block

``layer_pattern()`` returns the per-layer kind + whether the layer's FFN is MoE.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    # -- identity ---------------------------------------------------------
    name: str
    arch_type: str                      # dense | moe | hybrid | ssm | vlm | audio
    source: str = ""                    # citation (hf: / arXiv:)

    # -- trunk ------------------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                   # 0 -> d_model // n_heads
    d_ff: int = 1024                    # dense MLP hidden (0 = no MLP, pure SSM)
    vocab_size: int = 1024
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    activation: str = "swiglu"          # swiglu | gelu
    rope_theta: float = 10_000.0
    pos_embedding: str = "rope"         # rope | learned | none
    tie_embeddings: bool = False
    max_seq_len: int = 1 << 20

    # -- MoE ---------------------------------------------------------------
    n_experts: int = 0                  # routed experts (0 = dense)
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0                   # expert hidden dim (0 -> d_ff)
    moe_every: int = 1                  # MoE FFN on layers where l % moe_every == moe_offset
    moe_offset: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25

    # -- hybrid / SSM -------------------------------------------------------
    attn_every: int = 1                 # hybrid: attention on l % attn_every == attn_offset,
    attn_offset: int = 0                #         SSM (mamba) elsewhere
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0                # xlstm: sLSTM on l % slstm_every == 1 (0 = none)

    # -- encoder/decoder (audio) --------------------------------------------
    is_enc_dec: bool = False
    n_enc_layers: int = 0
    n_frames: int = 0                   # stubbed frontend: encoder frames per example

    # -- VLM stub frontend ----------------------------------------------------
    n_patches: int = 0                  # stubbed vision tower: patch embeddings per example

    # -- serving ----------------------------------------------------------
    sliding_window: int = 0             # 0 = full attention; >0 = SWA window
    kv_dtype: str = ""                  # "" = dtype; "int8" = quantized cache

    # -- numerics -----------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # ------------------------------------------------------------------ api
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_ff(self) -> int:
        return self.d_expert or self.d_ff

    @property
    def n_dec_layers(self) -> int:
        return self.n_layers

    def layer_pattern(self) -> Tuple[Tuple[str, bool], ...]:
        """Per-decoder-layer (block_kind, is_moe_ffn)."""
        out = []
        for l in range(self.n_layers):
            if self.slstm_every:
                kind = "slstm" if (l % self.slstm_every == 1) else "mlstm"
            elif self.attn_every > 1:
                kind = "attn" if (l % self.attn_every == self.attn_offset) else "mamba"
            elif self.arch_type == "ssm":
                kind = "mlstm"
            else:
                kind = "attn"
            moe = self.is_moe and (l % self.moe_every == self.moe_offset)
            out.append((kind, moe))
        return tuple(out)

    # --------------------------------------------------------- param counts
    def _ffn_params(self, moe: bool) -> int:
        d = self.d_model
        if moe:
            per = (3 if self.activation == "swiglu" else 2) * d * self.expert_ff
            routed = self.n_experts * per
            shared = self.n_shared_experts * per
            router = d * self.n_experts
            return routed + shared + router
        if self.d_ff == 0:
            return 0
        mult = 3 if self.activation == "swiglu" else 2
        return mult * d * self.d_ff

    def _ffn_active_params(self, moe: bool) -> int:
        if not moe:
            return self._ffn_params(False)
        d = self.d_model
        per = (3 if self.activation == "swiglu" else 2) * d * self.expert_ff
        return (self.top_k + self.n_shared_experts) * per + d * self.n_experts

    def _block_params(self, kind: str) -> int:
        d, hd = self.d_model, self.hd
        if kind == "attn":
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            return q + kv + o + 2 * d          # + norms
        if kind == "mamba":
            di = self.ssm_expand * d
            in_proj = d * 2 * di
            conv = di * self.ssm_d_conv
            xproj = di * (2 * self.ssm_d_state + di // 16 + 1)  # B,C,dt(lowrank~di/16)
            dtp = di // 16 * di
            out = di * d
            return in_proj + conv + xproj + dtp + out + di + d  # + A,D-ish + norm
        if kind == "mlstm":
            di = self.ssm_expand * d
            nh = max(self.n_heads, 1)
            # split up-proj, block-diagonal per-head q/k/v, i/f gates, down-proj
            return (2 * d * di + 3 * di * di // nh + 2 * di * nh
                    + di + di * d + d)
        if kind == "slstm":
            nh = max(self.n_heads, 1)
            # 4 input gate mats + block-diagonal recurrent + bias
            return 4 * d * d + 4 * d * d // nh + 4 * d + d
        raise ValueError(kind)

    def param_count(self) -> int:
        """Total trunk+embedding params (used for MODEL_FLOPS and memory napkin)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for kind, moe in self.layer_pattern():
            n += self._block_params(kind) + self._ffn_params(moe)
        if self.is_enc_dec:
            for _ in range(self.n_enc_layers):
                n += self._block_params("attn") + self._ffn_params(False)
                n += self._block_params("attn")  # decoder cross-attn counted here
        n += self.d_model
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for kind, moe in self.layer_pattern():
            n += self._block_params(kind) + self._ffn_active_params(moe)
        if self.is_enc_dec:
            for _ in range(self.n_enc_layers):
                n += self._block_params("attn") + self._ffn_params(False)
                n += self._block_params("attn")
        n += self.d_model
        return n

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant of the same family: <=2 layers (enc-dec: 2+2),
    d_model<=256, <=4 experts, tiny vocab/frontends.  Keeps kind pattern."""
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    n_layers = min(cfg.n_layers, 2 if cfg.attn_every <= 1 and not cfg.slstm_every else 8)
    kw = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=64 if cfg.head_dim else 0,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        d_expert=min(cfg.expert_ff, 256) if cfg.n_experts else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        n_frames=min(cfg.n_frames, 16),
        n_patches=min(cfg.n_patches, 16),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        dtype="float32",
        param_dtype="float32",
    )
    return cfg.replace(**kw)


# --------------------------------------------------------------------------
# The paper's own workload: distributed sleep-stage classification.
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class SleepConfig:
    """Sleep-EDF classification per the paper (§2.2–2.4)."""
    n_classes: int = 6                  # W, 1, 2, 3, 4, REM
    n_features: int = 75                # 15 stats x 5 bands (§2.3)
    n_bands: int = 5
    sample_rate: int = 100              # Hz (sleep-EDF EEG)
    epoch_seconds: int = 30             # R&K scoring epoch
    transform: str = "none"             # none | pca | svd   (paper: C / PCA / SVD)
    pca_dims: int = 16
    seed: int = 0

    @property
    def epoch_len(self) -> int:
        return self.sample_rate * self.epoch_seconds   # 3000 samples

    # 5 bands per Rechtschaffen & Kales frequency ranges (paper Table 1)
    BANDS: Tuple[Tuple[str, float, float], ...] = (
        ("delta", 0.5, 4.0),
        ("theta", 4.0, 8.0),
        ("alpha", 8.0, 12.0),
        ("spindle", 12.0, 15.0),
        ("beta", 15.0, 30.0),
    )
