"""Whisper-medium — encoder-decoder audio transformer.  [arXiv:2212.04356]

The mel-spectrogram + conv1d feature extractor is a STUB per the carve-out:
``input_specs()`` supplies conv-output frame embeddings (batch, 1500, d_model).
Decoder: learned positions, LayerNorm, GeLU, cross-attention to the encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    source="arXiv:2212.04356",
    n_layers=24,            # decoder layers
    n_enc_layers=24,
    is_enc_dec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,          # GQA kv=16 (full MHA)
    d_ff=4096,
    vocab_size=51_865,
    norm="layernorm",
    activation="gelu",
    pos_embedding="learned",
    n_frames=1500,          # 30 s audio -> 1500 conv frames
)
