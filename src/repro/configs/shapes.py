"""Assigned input shapes.

``kind`` selects which step gets lowered:
  train    -> train_step(tokens, labels)
  prefill  -> serve_prefill (process seq, emit logits + KV cache)
  decode   -> serve_step (ONE new token against a KV cache of seq_len)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES: Tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}
