"""AdamW in pure JAX, with configurable moment dtype.

Moments default to fp32; for the largest archs (jamba-398B) bf16 moments are
required to fit 256 chips (EXPERIMENTS.md §Dry-run quantifies this: fp32
Adam states need 21.8 GB/chip at 256-way full sharding, over the v5e 16 GB;
bf16 moments bring it to 8.7 GB).  Optimizer state shards exactly like the
parameters (ZeRO).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    moment_dtype: str = "float32"       # float32 | bfloat16


def lr_at(oc: OptConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = oc.lr * step / max(oc.warmup_steps, 1)
    t = jnp.clip((step - oc.warmup_steps) /
                 max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * oc.lr * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < oc.warmup_steps, warm, cos)


def adamw_init(params, oc: OptConfig):
    mdt = jnp.dtype(oc.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt, params, oc: OptConfig):
    """Returns (new_params, new_opt, metrics)."""
    step = opt["step"] + 1
    lr = lr_at(oc, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if oc.grad_clip else 1.0
    mdt = jnp.dtype(oc.moment_dtype)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - oc.b1 ** t
    bc2 = 1.0 - oc.b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * oc.b1 + (1 - oc.b1) * g
        v32 = v.astype(jnp.float32) * oc.b2 + (1 - oc.b2) * g * g
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + oc.eps)
        wd = oc.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (u + wd)
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt["m"])
    flat_v = treedef.flatten_up_to(opt["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
