from repro.train.optimizer import OptConfig, adamw_init, adamw_update, lr_at
from repro.train.loop import TrainConfig, make_train_step, make_prefill, make_serve_step, init_state
from repro.train import checkpoint
