"""Step factories: train / prefill / serve, with full sharding plumbing.

``make_train_step(cfg, mesh, ...)`` returns a jitted SPMD step whose
in/out shardings implement the paper's data-parallel scheme (batch over
``data``/``pod``, gradients all-reduced — Spark treeAggregate on ICI) plus
TP/FSDP for the big archs.  ``make_prefill``/``make_serve_step`` build the
serving path with the 2-D-sharded KV cache (DESIGN §5).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import transformer as tf
from repro.models.kvcache import init_cache
from repro.sharding import specs as specs_lib
from repro.sharding.axes import MeshAxes, axes_from_mesh
from repro.train.optimizer import OptConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    q_chunk: int = 1024
    window: int = 0                 # train-time SWA window (0 = cfg default)
    microbatches: int = 0           # 0 = auto (bound per-device live tokens)
    zero1: bool = False             # ZeRO-1: shard only optimizer state


def auto_microbatches(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                      axes: MeshAxes, target_tokens_per_dev: int = 16384) -> int:
    """Gradient-accumulation split: bounds the rematted activation stack
    (n_layers x tokens_per_dev x d bytes) per device."""
    d_ways = 1
    for a in axes.data:
        d_ways *= mesh.shape[a]
    if shape.global_batch % d_ways:
        return 1
    local_tokens = (shape.global_batch // d_ways) * shape.seq_len
    k = max(1, local_tokens // target_tokens_per_dev)
    # k must divide the local batch
    local_b = shape.global_batch // d_ways
    while local_b % k:
        k -= 1
    return max(k, 1)


def _ctx(cfg, mesh, axes, *, batch_sharded, fsdp, q_chunk, window):
    return tf.Context(mesh=mesh, axes=axes, batch_sharded=batch_sharded,
                      fsdp=fsdp, q_chunk=q_chunk,
                      window=window if window else cfg.sliding_window)


def cross_entropy(logits, labels):
    """logits (B,S,V) fp32; labels (B,S) int32, -1 = ignore."""
    mask = labels >= 0
    lab = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1)
    return nll.sum() / denom, denom


def loss_fn(params, batch, cfg: ModelConfig, ctx: tf.Context):
    h, _, aux = tf.forward(params, cfg, batch["tokens"], ctx,
                           frontend=batch.get("frontend"))
    if cfg.n_patches:                       # loss on text positions only
        h = h[:, cfg.n_patches:]
    logits = tf.unembed(params, cfg, h)
    ce, _ = cross_entropy(logits, batch["labels"])
    return ce + aux, {"ce": ce, "aux": aux}


def init_state(rng, cfg: ModelConfig, tc: TrainConfig):
    params = tf.init_params(rng, cfg)
    return {"params": params, "opt": adamw_init(params, tc.opt)}


def state_specs(cfg: ModelConfig, mesh: Mesh, axes: MeshAxes, fsdp: bool,
                zero1: bool = False):
    """zero1: shard ONLY the optimizer moments over data (params replicated
    over data, TP over model).  The update step then reduce-scatters grads
    to the moment sharding and all-gathers params ONCE per step — vs
    ZeRO-3's per-layer-per-microbatch weight gathers (EXPERIMENTS.md §Perf).
    """
    sb = specs_lib.build(cfg, mesh, axes, fsdp)
    ps = sb.param_specs()
    if zero1:
        ps = specs_lib.build(cfg, mesh, axes, False).param_specs()
        ms = sb.param_specs()       # moments keep the data-sharded layout
        return {"params": ps, "opt": {"m": ms, "v": ms, "step": P()}}
    return {
        "params": ps,
        "opt": {"m": ps, "v": ps, "step": P()},
    }


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                axes: MeshAxes):
    sb = specs_lib.build(cfg, mesh, axes, fsdp=False)
    bax = sb.batch_spec(shape.global_batch)
    out = {"tokens": P(bax, None)}
    if shape.kind == "train":
        out["labels"] = P(bax, None)
    if cfg.n_patches or cfg.is_enc_dec:
        out["frontend"] = P(bax, None, None)
    return out


def make_train_step(cfg: ModelConfig, mesh: Mesh, tc: TrainConfig,
                    shape: InputShape, *, fsdp: Optional[bool] = None,
                    donate: bool = True):
    axes = axes_from_mesh(mesh)
    if fsdp is None:
        fsdp = specs_lib.auto_fsdp(cfg, mesh, axes)
    sspecs = state_specs(cfg, mesh, axes, fsdp, zero1=tc.zero1)
    bspecs = batch_specs(cfg, shape, mesh, axes)
    bsharded = bspecs["tokens"][0] is not None
    # under ZeRO-1 the forward sees replicated-over-data params (no gathers)
    ctx = _ctx(cfg, mesh, axes, batch_sharded=bsharded,
               fsdp=fsdp and not tc.zero1,
               q_chunk=tc.q_chunk, window=tc.window)
    k = tc.microbatches or auto_microbatches(cfg, shape, mesh, axes)

    def grad_fn(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch, cfg, ctx)

    def step(state, batch):
        if k == 1:
            (loss, metrics), grads = grad_fn(state["params"], batch)
        else:
            # gradient accumulation: scan over k microbatches (batch-major
            # split keeps each microbatch data-sharded); the fp32 accumulator
            # is pinned to the MOMENT sharding, so under ZeRO-1 each
            # microbatch's gradient sync lowers to a reduce-scatter (1/N
            # bytes) instead of a full all-reduce (EXPERIMENTS.md §Perf)
            mb = jax.tree.map(
                lambda a: a.reshape(k, a.shape[0] // k, *a.shape[1:]), batch)
            mspecs = sspecs["opt"]["m"]

            def pin(t):
                return jax.tree.map(
                    lambda a, sp: jax.lax.with_sharding_constraint(a, sp),
                    t, mspecs,
                    is_leaf=lambda x: not isinstance(x, dict))

            gz = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  state["params"]))

            def acc(carry, mbi):
                g_acc, l_acc = carry
                (l, _m), g = grad_fn(state["params"], mbi)
                g_acc = pin(jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g))
                return (g_acc, l_acc + l), None

            (grads, lsum), _ = jax.lax.scan(acc, (gz, jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = lsum / k
            metrics = {"ce": loss, "aux": jnp.float32(0.0)}
        new_params, new_opt, om = adamw_update(
            grads, state["opt"], state["params"], tc.opt)
        metrics = dict(metrics, loss=loss, **om)
        return {"params": new_params, "opt": new_opt}, metrics

    in_sh = (specs_lib.named(mesh, sspecs), specs_lib.named(mesh, bspecs))
    out_sh = (specs_lib.named(mesh, sspecs), None)
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0,) if donate else ()), sspecs, bspecs, ctx


def make_prefill(cfg: ModelConfig, mesh: Mesh, shape: InputShape, *,
                 q_chunk: int = 1024, fsdp: Optional[bool] = None):
    axes = axes_from_mesh(mesh)
    if fsdp is None:
        fsdp = specs_lib.auto_fsdp_serving(cfg, mesh, axes)
    sb = specs_lib.build(cfg, mesh, axes, fsdp)
    pspecs = sb.param_specs()
    bspecs = batch_specs(cfg, shape, mesh, axes)
    cspecs = sb.cache_specs(shape)
    bsharded = bspecs["tokens"][0] is not None
    ctx = _ctx(cfg, mesh, axes, batch_sharded=bsharded, fsdp=fsdp,
               q_chunk=q_chunk, window=0)

    def pf(params, batch):
        return tf.prefill(params, cfg, batch["tokens"], ctx,
                          frontend=batch.get("frontend"))

    in_sh = (specs_lib.named(mesh, pspecs), specs_lib.named(mesh, bspecs))
    out_sh = (None, specs_lib.named(mesh, cspecs))
    return jax.jit(pf, in_shardings=in_sh, out_shardings=out_sh), \
        pspecs, bspecs, cspecs, ctx


def make_serve_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape, *,
                    fsdp: Optional[bool] = None, donate: bool = True):
    """ONE-token decode step against a seq_len cache (decode shapes)."""
    axes = axes_from_mesh(mesh)
    if fsdp is None:
        fsdp = specs_lib.auto_fsdp_serving(cfg, mesh, axes)
    sb = specs_lib.build(cfg, mesh, axes, fsdp)
    pspecs = sb.param_specs()
    cspecs = sb.cache_specs(shape)
    bax = sb.batch_spec(shape.global_batch)
    bsharded = bax is not None
    ctx = _ctx(cfg, mesh, axes, batch_sharded=bsharded, fsdp=fsdp,
               q_chunk=1, window=0)

    def step(params, token, cache, pos):
        return tf.decode_step(params, cfg, token, cache, pos, ctx)

    in_sh = (specs_lib.named(mesh, pspecs),
             NamedSharding(mesh, P(bax, None)),
             specs_lib.named(mesh, cspecs),
             NamedSharding(mesh, P()))
    out_sh = (None, specs_lib.named(mesh, cspecs))
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(2,) if donate else ()), \
        pspecs, cspecs, ctx
