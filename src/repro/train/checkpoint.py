"""Sharding-aware checkpointing (npz + JSON manifest).

Leaves are saved host-side as one ``.npz`` keyed by flattened tree paths;
``restore`` rebuilds the pytree and ``device_put``s each leaf to its target
sharding.  Good for single-host CPU validation and structurally identical to
a per-shard production layout (the sharding argument is where a multi-host
writer would split).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, state, step: Optional[int] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)
    np.savez(os.path.join(path, "state.npz"), **flat)
    meta = {
        "keys": sorted(flat),
        "step": int(step) if step is not None else None,
        "treedef": str(jax.tree_util.tree_structure(state)),
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(meta, f, indent=1)


def restore(path: str, target, shardings=None):
    """target: pytree of arrays or ShapeDtypeStructs with the same structure."""
    data = np.load(os.path.join(path, "state.npz"))
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_p))
    out = []
    for (pth, tgt), sh in zip(leaves_p, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
        arr = jnp.asarray(data[key], dtype=tgt.dtype)
        if arr.shape != tuple(tgt.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {tgt.shape}")
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[-1]) for d in os.listdir(root)
             if d.startswith("step_")]
    return max(steps) if steps else None
