"""75-feature extraction per paper §2.3: 15 statistics x 5 R&K bands.

Pipeline: rFFT band-split (exact brick-wall masks on the 5 bands) ->
sort each (epoch, band) row (XLA sort) -> fused 15-statistic reduction.
Sorting first makes every statistic either a plain reduction or an indexed
read (min/median/max/quantiles/trimmed mean), which is what lets the Pallas
``band_stats`` kernel produce all 75 features in one VMEM pass (DESIGN §2).

The 15 statistics (paper order; xiv "skewness" is listed twice in the paper —
we use |skewness| for slot xiv and note it in DESIGN §6):
  1 arithmetic mean, 2 harmonic mean (of |x|), 3 trimmed mean (outliers
  beyond q25/q75 excluded), 4 energy, 5 energy entropy, 6 min, 7 median,
  8 max, 9 std, 10 skewness, 11 q25, 12 q75, 13 IQR, 14 |skewness|,
  15 kurtosis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SleepConfig

FEATURE_NAMES = tuple(
    f"{band}_{stat}"
    for band in ("delta", "theta", "alpha", "spindle", "beta")
    for stat in ("mean", "hmean", "trimmed_mean", "energy", "entropy",
                 "min", "median", "max", "std", "skew", "q25", "q75",
                 "iqr", "abs_skew", "kurtosis"))


def band_split(X, cfg: SleepConfig = SleepConfig()):
    """X (n, T) -> (n, 5, T) brick-wall band-passed signals."""
    T = X.shape[-1]
    spec = jnp.fft.rfft(X, axis=-1)                        # (n, T//2+1)
    freqs = jnp.fft.rfftfreq(T, 1.0 / cfg.sample_rate)
    outs = []
    for _name, lo, hi in cfg.BANDS:
        mask = ((freqs >= lo) & (freqs < hi)).astype(spec.dtype)
        outs.append(jnp.fft.irfft(spec * mask[None], n=T, axis=-1))
    return jnp.stack(outs, axis=1).astype(jnp.float32)


def extract_features(X, cfg: SleepConfig = SleepConfig(),
                     use_kernel: bool = True):
    """X (n, T) raw epochs -> (n, 75) float32 features."""
    bands = band_split(X, cfg)                             # (n,5,T)
    bands_sorted = jnp.sort(bands, axis=-1)
    from repro.kernels import ops as kops
    fn = kops.band_stats if use_kernel else kops.band_stats_ref
    feats = fn(bands_sorted)                               # (n,5,15)
    return feats.reshape(X.shape[0], -1)
