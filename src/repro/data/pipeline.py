"""Host-side data pipeline for both workloads.

* ``make_dataset``: synthesize EEG -> extract 75 features -> normalize ->
  train/test split, with batch placement onto the mesh data axis (the
  classifier path — DistContext.shard_batch does device placement).
* ``token_stream``: synthetic token batches for the LM training driver
  (deterministic per-step keys so runs are reproducible/resumable).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SleepConfig
from repro.data.features import extract_features
from repro.data.synthetic_eeg import synth_epochs


def make_dataset(n_train: int, n_test: int, cfg: SleepConfig = SleepConfig(),
                 seed: int = 0, chunk: int = 4096, use_kernel: bool = True
                 ) -> Dict[str, jnp.ndarray]:
    """Synthesize + featurize in chunks (bounds FFT memory), z-normalize."""
    key = jax.random.PRNGKey(seed)
    total = n_train + n_test
    feats, labels = [], []
    extract = jax.jit(lambda x: extract_features(x, cfg, use_kernel=use_kernel))
    for i in range(0, total, chunk):
        k = jax.random.fold_in(key, i)
        m = min(chunk, total - i)
        X, y = synth_epochs(k, m, cfg)
        feats.append(np.asarray(extract(X)))
        labels.append(np.asarray(y))
    X = np.concatenate(feats)
    y = np.concatenate(labels)
    mu = X[:n_train].mean(0)
    sd = X[:n_train].std(0) + 1e-6
    X = (X - mu) / sd
    return {
        "X_train": jnp.asarray(X[:n_train]), "y_train": jnp.asarray(y[:n_train]),
        "X_test": jnp.asarray(X[n_train:]), "y_test": jnp.asarray(y[n_train:]),
    }


def token_stream(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                 start_step: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    """Synthetic LM batches: Zipf-ish token draws + shifted labels, plus the
    stubbed frontend embeddings for VLM/audio archs."""
    key = jax.random.PRNGKey(seed)
    step = start_step
    n_text = seq - (cfg.n_patches or 0)
    ranks = jnp.arange(1, cfg.vocab_size + 1, dtype=jnp.float32)
    probs = (1.0 / ranks) / jnp.sum(1.0 / ranks)
    while True:
        k = jax.random.fold_in(key, step)
        k1, k2 = jax.random.split(k)
        toks = jax.random.choice(k1, cfg.vocab_size, (batch, n_text + 1),
                                 p=probs)
        out = {"tokens": toks[:, :-1].astype(jnp.int32),
               "labels": toks[:, 1:].astype(jnp.int32)}
        if cfg.n_patches:
            out["frontend"] = 0.02 * jax.random.normal(
                k2, (batch, cfg.n_patches, cfg.d_model))
        elif cfg.is_enc_dec:
            out["frontend"] = 0.02 * jax.random.normal(
                k2, (batch, cfg.n_frames, cfg.d_model))
        yield out
        step += 1
