"""Stage-conditioned synthetic sleep EEG (the PhysioNet data gate, DESIGN §3).

Each 30 s / 100 Hz epoch is synthesized from the paper's Table 1: a bank of
band-limited oscillators at the stage's characteristic frequencies and
amplitudes (alpha/beta for W and REM, theta for S1, spindles for S2/S3,
delta/slow waves for S3/S4), plus 1/f background noise, amplitude jitter,
and occasional artifact spikes.  Bands overlap and noise is substantial, so
the task is learnable but not trivial — classifier rankings land in the
paper's regime (LR/DT ~0.8, NB lower, PCA lossy).

Stages: 0=W, 1=S1, 2=S2, 3=S3, 4=S4, 5=REM (R&K six-class scheme, §2.2).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SleepConfig

STAGE_NAMES = ("W", "S1", "S2", "S3", "S4", "REM")

# per-stage oscillator banks: (freq_lo, freq_hi, amplitude) per Table 1
_STAGE_OSC = (
    ((15.0, 30.0, 22.0), (8.0, 12.0, 18.0), (30.0, 48.0, 8.0)),    # W
    ((4.0, 8.0, 60.0), (8.0, 12.0, 14.0), (15.0, 25.0, 8.0)),      # S1
    ((4.0, 15.0, 55.0), (12.0, 15.0, 55.0), (0.5, 2.0, 12.0)),     # S2 spindles
    ((2.0, 4.0, 90.0), (12.0, 15.0, 35.0), (0.5, 2.0, 45.0)),      # S3
    ((0.5, 2.0, 140.0), (2.0, 4.0, 45.0), (12.0, 15.0, 10.0)),     # S4
    ((15.0, 30.0, 20.0), (2.0, 6.0, 16.0), (8.0, 12.0, 10.0)),     # REM sawtooth-ish
)

# realistic-ish stage prevalence over a night (S2 dominates)
STAGE_PROBS = (0.18, 0.09, 0.40, 0.10, 0.06, 0.17)


def _pink_noise(key, n, T, sample_rate):
    """1/f noise via spectral shaping."""
    nf = T // 2 + 1
    k1, k2 = jax.random.split(key)
    mag = jax.random.normal(k1, (n, nf)) + 1j * jax.random.normal(k2, (n, nf))
    freqs = jnp.fft.rfftfreq(T, 1.0 / sample_rate)
    shape = 1.0 / jnp.sqrt(jnp.maximum(freqs, 0.5))
    return jnp.fft.irfft(mag * shape[None], n=T, axis=-1) * jnp.sqrt(T) * 0.5


# expert-label confusion: R&K scoring has ~80-85% inter-rater agreement;
# mislabels go to spectrally adjacent stages (W<->S1<->REM, S2<->S3<->S4)
LABEL_NOISE = 0.16
_ADJACENT = ((1, 5), (0, 2), (1, 3), (2, 4), (3, 2), (0, 1))


def synth_epochs(key, n: int, cfg: SleepConfig = SleepConfig()
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (X (n, epoch_len) float32 microvolts, y (n,) int32 stages).

    y is the *assigned* (expert) label: the signal is synthesized from the
    true stage, then LABEL_NOISE of labels flip to an adjacent stage —
    capping achievable accuracy near the paper's ~0.82 regime (DESIGN §3).
    """
    T = cfg.epoch_len
    fs = cfg.sample_rate
    ks = jax.random.split(key, 12)
    y = jax.random.choice(ks[0], cfg.n_classes, (n,),
                          p=jnp.asarray(STAGE_PROBS))
    t = jnp.arange(T) / fs                                        # (T,)

    osc = jnp.asarray(_STAGE_OSC)                                 # (6,3,3)
    lo = osc[y][:, :, 0]                                          # (n,3)
    hi = osc[y][:, :, 1]
    amp = osc[y][:, :, 2]

    f = lo + (hi - lo) * jax.random.uniform(ks[1], lo.shape)      # freq draw
    phase = jax.random.uniform(ks[2], lo.shape) * 2 * jnp.pi
    amp = amp * (0.7 + 0.6 * jax.random.uniform(ks[3], amp.shape))
    # slow amplitude modulation (spindle trains / K-complex bursts)
    mod_f = 0.2 + 0.6 * jax.random.uniform(ks[4], amp.shape)
    mod_p = jax.random.uniform(ks[5], amp.shape) * 2 * jnp.pi
    carrier = jnp.sin(2 * jnp.pi * f[..., None] * t + phase[..., None])
    envelope = 0.6 + 0.4 * jnp.sin(2 * jnp.pi * mod_f[..., None] * t
                                   + mod_p[..., None])
    x = jnp.sum(amp[..., None] * carrier * envelope, axis=1)      # (n,T)

    x = x + 30.0 * _pink_noise(ks[6], n, T, fs)
    # sparse artifact spikes (electrode pops / eye blinks)
    spike_mask = (jax.random.uniform(ks[7], (n, T)) < 5e-4).astype(jnp.float32)
    x = x + spike_mask * 120.0 * jax.random.normal(ks[8], (n, T))
    # per-epoch electrode gain variability (subject/montage differences)
    gain = jnp.exp(0.35 * jax.random.normal(ks[9], (n, 1)))
    x = x * gain

    # expert-label confusion to adjacent stages
    adj = jnp.asarray(_ADJACENT)                                  # (6,2)
    flip = jax.random.uniform(ks[10], (n,)) < LABEL_NOISE
    which = jax.random.randint(ks[11], (n,), 0, 2)
    y_noisy = jnp.where(flip, adj[y, which], y)
    return x.astype(jnp.float32), y_noisy.astype(jnp.int32)
