from repro.data.synthetic_eeg import STAGE_NAMES, synth_epochs
from repro.data.features import extract_features, FEATURE_NAMES
from repro.data.pipeline import make_dataset, token_stream
