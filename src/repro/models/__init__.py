from repro.models.transformer import (
    Context,
    block_period,
    decode_step,
    forward,
    init_params,
    prefill,
    unembed,
)
from repro.models.kvcache import cache_layout, cache_struct, init_cache
