"""Recurrent blocks: Mamba selective SSM, xLSTM (mLSTM / sLSTM).

The xLSTM blocks additionally have *explicitly sharded* variants
(``mlstm_block_sharded`` / ``slstm_block_sharded``): the baseline pjit
lowering let XLA re-shard the chunk-loop einsums every iteration
("involuntary full rematerialization" — ~1.65 TB/step of all-reduce inside
the sLSTM time loop at 256 chips, EXPERIMENTS.md §Perf).  The shard_map
variants pin the layout — batch over ``data``, value-dim TP over ``model``
with exactly ONE psum per block, FSDP weight gathers at entry — and are
what the production step uses.

All recurrences carry fp32 state; sequence processing is *chunked*:
a `lax.scan` over chunks carries the recurrent state, and within a chunk the
first-order recurrence runs as a `lax.associative_scan` (log-depth on TPU).
The chunk size bounds the (B, Tc, d_inner, N) discretized-parameter tensors
that a naive Mamba materializes for the whole sequence (DESIGN §5).

Decode paths are single-step state updates (O(1) per token) — these are what
``long_500k`` exercises.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import normal

SSM_CHUNK = 256


# =============================================================== mamba =====
def init_mamba(key, cfg: ModelConfig, d: int) -> dict:
    di = cfg.ssm_expand * d
    N, dc = cfg.ssm_d_state, cfg.ssm_d_conv
    R = max(1, di // 16)                         # dt low-rank
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": normal(ks[0], (d, 2 * di), d ** -0.5, dt),
        "conv_w": normal(ks[1], (dc, di), dc ** -0.5, jnp.float32),
        "x_proj": normal(ks[2], (di, R + 2 * N), di ** -0.5, dt),
        "dt_proj": normal(ks[3], (R, di), R ** -0.5, jnp.float32),
        "dt_bias": jnp.zeros((di,), jnp.float32) - 4.6,   # softplus(-4.6) ~ 0.01
        "A_log": jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32))[None, :]
                 * jnp.ones((di, 1), jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": normal(ks[4], (di, d), di ** -0.5, dt),
    }


def _mamba_inner(xc, Bc, Cc, dtc, A, h0):
    """One chunk of the selective scan.
    xc: (B,Tc,di), Bc/Cc: (B,Tc,N), dtc: (B,Tc,di), A: (di,N), h0: (B,di,N).
    Returns (y (B,Tc,di), hT)."""
    da = jnp.exp(dtc[..., None] * A)                              # (B,Tc,di,N)
    db = dtc[..., None] * Bc[:, :, None, :] * xc[..., None]       # (B,Tc,di,N)

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_sc, b_sc = jax.lax.associative_scan(comb, (da, db), axis=1)
    h = a_sc * h0[:, None] + b_sc                                  # (B,Tc,di,N)
    y = jnp.einsum("btdn,btn->btd", h, Cc)
    return y, h[:, -1]


def _causal_dwconv(x, w, state=None):
    """Depthwise causal conv.  x: (B,S,di), w: (dc,di).
    state: (B,dc-1,di) trailing context (decode) or None (zero-pad)."""
    dc = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                         # (B,S+dc-1,di)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None].astype(x.dtype)
            for i in range(dc))
    return y, xp[:, -(dc - 1):]                                    # new state


def mamba_block(x, p, cfg: ModelConfig, *, chunk: int = SSM_CHUNK):
    """Full-sequence Mamba (train/prefill).  x: (B,S,d) -> (y, final_state)."""
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    N = cfg.ssm_d_state
    R = p["dt_proj"].shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xp, z = jnp.split(xz, 2, axis=-1)
    xp, conv_state = _causal_dwconv(xp, p["conv_w"])
    xp = jax.nn.silu(xp.astype(jnp.float32))
    proj = jnp.einsum("bsd,de->bse", xp.astype(x.dtype), p["x_proj"])
    dt_r, Bc, Cc = jnp.split(proj.astype(jnp.float32), [R, R + N], axis=-1)
    dtv = jax.nn.softplus(dt_r @ p["dt_proj"] + p["dt_bias"])      # (B,S,di)
    A = -jnp.exp(p["A_log"])                                       # (di,N)

    Tc = min(chunk, S)
    if S % Tc:
        Tc = S
    nc = S // Tc
    h0 = jnp.zeros((B, di, N), jnp.float32)

    if nc == 1:
        y, hT = _mamba_inner(xp, Bc, Cc, dtv, A, h0)
    else:
        # remat each chunk: the associative scan's linearization otherwise
        # saves its log-depth intermediate (B,Tc,di,N) products for backward
        # — tens of GB/layer at jamba scale (EXPERIMENTS.md §Perf)
        @jax.checkpoint
        def body(h, idx):
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * Tc, Tc, 1)
            y, hT = _mamba_inner(sl(xp), sl(Bc), sl(Cc), sl(dtv), A, h)
            return hT, y
        hT, ys = jax.lax.scan(body, h0, jnp.arange(nc))
        y = ys.swapaxes(0, 1).reshape(B, S, di)

    y = y + xp * p["D"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsd,de->bse", y.astype(x.dtype), p["out_proj"])
    return out, {"h": hT, "conv": conv_state.astype(jnp.float32)}


def mamba_decode(x1, p, cfg: ModelConfig, state):
    """One-token Mamba step.  x1: (B,1,d); state: {'h': (B,di,N), 'conv': (B,dc-1,di)}."""
    B = x1.shape[0]
    N = cfg.ssm_d_state
    R = p["dt_proj"].shape[0]
    xz = jnp.einsum("bsd,de->bse", x1, p["in_proj"])
    xp, z = jnp.split(xz, 2, axis=-1)
    xp, conv_state = _causal_dwconv(xp, p["conv_w"], state["conv"])
    xp = jax.nn.silu(xp.astype(jnp.float32))
    proj = jnp.einsum("bsd,de->bse", xp.astype(x1.dtype), p["x_proj"])
    dt_r, Bc, Cc = jnp.split(proj.astype(jnp.float32), [R, R + N], axis=-1)
    dtv = jax.nn.softplus(dt_r @ p["dt_proj"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dtv[..., None] * A)[:, 0]                          # (B,di,N)
    db = (dtv[..., None] * Bc[:, :, None, :] * xp[..., None])[:, 0]
    h = da * state["h"] + db
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])[:, None]
    y = y + xp * p["D"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsd,de->bse", y.astype(x1.dtype), p["out_proj"])
    return out, {"h": h, "conv": conv_state.astype(jnp.float32)}


# =============================================================== mLSTM =====
def init_mlstm(key, cfg: ModelConfig, d: int) -> dict:
    di = cfg.ssm_expand * d
    nh = cfg.n_heads
    hd = di // nh
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    return {
        # split up-projection: the x branch feeds q/k (must stay whole per
        # head); the z branch gates the value-sharded output, so it can be
        # tensor-parallel along di (DESIGN §5)
        "w_up_x": normal(ks[0], (d, di), d ** -0.5, dt),
        # head-major (nh, hd) layouts so the value-dim TP shard of z/norm/
        # down pairs index-for-index with the per-head value shard of h
        "w_up_z": normal(ks[6], (d, nh, hd), d ** -0.5, dt),
        # block-diagonal (per-head) q/k/v, as in the xLSTM paper
        "wq": normal(ks[1], (nh, hd, hd), hd ** -0.5, dt),
        "wk": normal(ks[2], (nh, hd, hd), hd ** -0.5, dt),
        "wv": normal(ks[3], (nh, hd, hd), hd ** -0.5, dt),
        "w_i": normal(ks[4], (di, nh), di ** -0.5, jnp.float32),
        "w_f": normal(ks[5], (di, nh), di ** -0.5, jnp.float32),
        "b_i": jnp.zeros((nh,), jnp.float32),
        "b_f": jnp.ones((nh,), jnp.float32) * 3.0,     # start remembering
        "mh_norm": jnp.ones((nh, hd), jnp.float32),
        "down_proj": normal(jax.random.fold_in(key, 7), (nh, hd, d), di ** -0.5, dt),
    }


def _mlstm_chunk(q, k, v, logf, logi, state):
    """One chunk of stabilized mLSTM (chunkwise-parallel linear attention).

    q,k,v: (B,Tc,nh,hd) fp32; logf/logi: (B,Tc,nh); state: (C,n,m,F):
      C: (B,nh,hd,hd), n: (B,nh,hd), m: (B,nh), F: (B,nh) cumulative log-decay.
    Math: with F_t = sum_{s<=t} logf_s (within all history),
      stabilizer  m_t = max(m_{t-1} + logf_t, ... ) realized as
      m_t = max_{s<=t}(F_t - F_s + logi_s) combined with carry-in m.
    """
    C0, n0, m0, F0 = state
    B, Tc, nh, hd = q.shape
    Fc = jnp.cumsum(logf, axis=1)                                  # (B,Tc,nh)
    # log weight of source s as seen at t: Fc_t - Fc_s + logi_s  (s <= t)
    a = logi - Fc                                                   # (B,Tc,nh)
    # m_t = max(Fc_t + running_max_s(a_s), Fc_t + m0): the carried state acts
    # like a source at position -1 with log-weight m0, decayed by Fc_t.
    m = Fc + jnp.maximum(jax.lax.cummax(a, axis=1), m0[:, None])
    # intra-chunk attention:  w_{t,s} = exp(Fc_t - Fc_s + logi_s - m_t), s<=t
    lw = Fc[:, :, None, :] - Fc[:, None, :, :] + logi[:, None, :, :] - m[:, :, None, :]
    tri = jnp.tril(jnp.ones((Tc, Tc), bool))
    w = jnp.where(tri[None, :, :, None], jnp.exp(lw), 0.0)          # (B,t,s,nh)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bthd,bshd->btsh", q, k) * scale
    h_intra = jnp.einsum("btsh,btsh,bshd->bthd", scores, w, v)
    n_intra = jnp.einsum("btsh,bshd->bthd", w, k)
    # inter-chunk: carry C0 decayed to t:  exp(Fc_t + m0 - m_t)
    dec = jnp.exp(Fc + m0[:, None] - m)                             # (B,Tc,nh)
    h_inter = jnp.einsum("bthd,bhde->bthe", q * dec[..., None], C0) * scale
    n_inter = n0[:, None] * dec[..., None]
    h_num = h_intra + h_inter
    n_all = n_intra + n_inter
    denom = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", q, n_all)) * scale,
                        jnp.exp(-m))
    h = h_num / denom[..., None]
    # chunk-final state
    mT = m[:, -1]
    wT = jnp.exp(Fc[:, -1:, :] - Fc + logi - mT[:, None])           # (B,Tc,nh)
    CT = jnp.exp(Fc[:, -1] + m0 - mT)[:, :, None, None] * C0 + \
         jnp.einsum("bsh,bshd,bshe->bhde", wT, k, v)
    nT = jnp.exp(Fc[:, -1] + m0 - mT)[:, :, None] * n0 + \
         jnp.einsum("bsh,bshd->bhd", wT, k)
    return h, (CT, nT, mT, F0 + Fc[:, -1])


def mlstm_block(x, p, cfg: ModelConfig, *, chunk: int = SSM_CHUNK):
    """Full-sequence mLSTM.  x: (B,S,d) -> (y, state)."""
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    nh = cfg.n_heads
    hd = di // nh
    xi = jnp.einsum("bsd,de->bse", x, p["w_up_x"])
    z = jnp.einsum("bsd,dhe->bshe", x, p["w_up_z"])      # (B,S,nh,hd)
    xh = xi.reshape(B, S, nh, hd)
    q = jnp.einsum("bshd,hde->bshe", xh, p["wq"]).astype(jnp.float32)
    k = jnp.einsum("bshd,hde->bshe", xh, p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bshd,hde->bshe", xh, p["wv"]).astype(jnp.float32)
    xif = xi.astype(jnp.float32)
    logi = xif @ p["w_i"] + p["b_i"]                                # (B,S,nh)
    logf = jax.nn.log_sigmoid(xif @ p["w_f"] + p["b_f"])

    Tc = min(chunk, S)
    if S % Tc:
        Tc = S
    nc = S // Tc
    state = (jnp.zeros((B, nh, hd, hd), jnp.float32),
             jnp.zeros((B, nh, hd), jnp.float32),
             jnp.full((B, nh), -1e30, jnp.float32),
             jnp.zeros((B, nh), jnp.float32))
    if nc == 1:
        h, state = _mlstm_chunk(q, k, v, logf, logi, state)
    else:
        @jax.checkpoint
        def body(st, idx):
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * Tc, Tc, 1)
            h, st = _mlstm_chunk(sl(q), sl(k), sl(v), sl(logf), sl(logi), st)
            return st, h
        state, hs = jax.lax.scan(body, state, jnp.arange(nc))
        h = hs.swapaxes(0, 1).reshape(B, S, nh, hd)

    h = h * p["mh_norm"]                                  # (B,S,nh,hd)
    h = h * jax.nn.silu(z.astype(jnp.float32))
    return jnp.einsum("bshe,hed->bsd", h.astype(x.dtype), p["down_proj"]), state


def mlstm_decode(x1, p, cfg: ModelConfig, state):
    """One-token mLSTM step."""
    B = x1.shape[0]
    d = x1.shape[-1]
    di = cfg.ssm_expand * d
    nh = cfg.n_heads
    hd = di // nh
    C0, n0, m0, F0 = state
    xi = jnp.einsum("bsd,de->bse", x1, p["w_up_x"])
    z = jnp.einsum("bsd,dhe->bshe", x1, p["w_up_z"])      # (B,1,nh,hd)
    xh = xi.reshape(B, 1, nh, hd)
    q = jnp.einsum("bshd,hde->bshe", xh, p["wq"]).astype(jnp.float32)[:, 0]
    k = jnp.einsum("bshd,hde->bshe", xh, p["wk"]).astype(jnp.float32)[:, 0]
    v = jnp.einsum("bshd,hde->bshe", xh, p["wv"]).astype(jnp.float32)[:, 0]
    xif = xi.astype(jnp.float32)[:, 0]
    logi = xif @ p["w_i"] + p["b_i"]                                # (B,nh)
    logf = jax.nn.log_sigmoid(xif @ p["w_f"] + p["b_f"])
    m = jnp.maximum(logf + m0, logi)
    fz = jnp.exp(logf + m0 - m)
    iz = jnp.exp(logi - m)
    C = fz[:, :, None, None] * C0 + iz[:, :, None, None] * jnp.einsum("bhd,bhe->bhde", k, v)
    n = fz[:, :, None] * n0 + iz[:, :, None] * k
    scale = hd ** -0.5
    num = jnp.einsum("bhd,bhde->bhe", q, C) * scale
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)) * scale, jnp.exp(-m))
    h = (num / den[..., None])[:, None] * p["mh_norm"]    # (B,1,nh,hd)
    h = h * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bshe,hed->bsd", h.astype(x1.dtype), p["down_proj"])
    return out, (C, n, m, F0 + logf)


# =============================================================== sLSTM =====
def init_slstm(key, cfg: ModelConfig, d: int) -> dict:
    nh = max(cfg.n_heads, 1)
    dh = d // nh
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2)
    return {
        "W": normal(ks[0], (d, 4 * d), d ** -0.5, dt),              # z,i,f,o
        "R": normal(ks[1], (nh, dh, 4 * dh), dh ** -0.5, jnp.float32),
        "b": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                              jnp.ones((d,), jnp.float32) * 3.0,
                              jnp.zeros((d,), jnp.float32)]),
    }


def _slstm_step(p, d, nh, st, wx_t):
    """st: (h,c,n,m) each (B,d) fp32; wx_t: (B,4d) input projection at t."""
    h, c, n, m = st
    dh = d // nh
    hh = h.reshape(-1, nh, dh)
    rec = jnp.einsum("bkd,kde->bke", hh, p["R"]).reshape(-1, 4 * d)
    g = wx_t + rec + p["b"]
    zr, ir, fr, orr = jnp.split(g, 4, axis=-1)
    lf = jax.nn.log_sigmoid(fr)
    mn = jnp.maximum(lf + m, ir)
    iz = jnp.exp(ir - mn)
    fz = jnp.exp(lf + m - mn)
    c = fz * c + iz * jnp.tanh(zr)
    n = fz * n + iz
    h = jax.nn.sigmoid(orr) * c / jnp.maximum(n, 1e-6)
    return (h, c, n, mn)


def slstm_block(x, p, cfg: ModelConfig):
    """Full-sequence sLSTM (sequential scan).  x: (B,S,d) -> (y, state)."""
    B, S, d = x.shape
    nh = max(cfg.n_heads, 1)
    wx = jnp.einsum("bsd,de->bse", x, p["W"]).astype(jnp.float32)
    st = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(3)) + \
         (jnp.full((B, d), -1e30, jnp.float32),)

    def body(st, wx_t):
        st = _slstm_step(p, d, nh, st, wx_t)
        return st, st[0]

    st, hs = jax.lax.scan(body, st, wx.swapaxes(0, 1))
    return hs.swapaxes(0, 1).astype(x.dtype), st


def slstm_decode(x1, p, cfg: ModelConfig, state):
    d = x1.shape[-1]
    nh = max(cfg.n_heads, 1)
    wx = jnp.einsum("bsd,de->bse", x1, p["W"]).astype(jnp.float32)[:, 0]
    st = _slstm_step(p, d, nh, state, wx)
    return st[0][:, None].astype(x1.dtype), st


# ================================================== explicit-shard variants
def _gather_fsdp(w, axis_name, axis: int):
    return jax.lax.all_gather(w, axis_name, axis=axis, tiled=True)


def mlstm_block_sharded(x, p, cfg: ModelConfig, *, mesh, axes, batch_sharded: bool,
                        fsdp: bool, chunk: int = SSM_CHUNK):
    """mLSTM with pinned SPMD layout (see module docstring).

    Layout: x (B,S,d) batch-sharded over ``axes.data``; q/k replicated over
    ``model``; the z-branch, value projection, mh_norm and down-projection
    are TP-sharded on the inner dim; one psum over ``model`` at the end.
    """
    from jax.sharding import PartitionSpec as P
    bspec = P(axes.data, None, None) if batch_sharded else P(None, None, None)
    f = axes.fsdp if fsdp else None
    m = axes.model
    di = cfg.ssm_expand * cfg.d_model
    nh = cfg.n_heads
    tp_ok = (di // nh) % mesh.shape[m] == 0 and (di % mesh.shape[m] == 0)
    mz = m if tp_ok else None

    def local(x, w_up_x, w_up_z, wq, wk, wv, w_i, w_f, b_i, b_f, mh_norm, down):
        from repro.models.layers import bf16_grad_barrier
        x = bf16_grad_barrier(x)   # x-cotangent crosses the model-psum in bf16
        if fsdp:
            w_up_x = _gather_fsdp(w_up_x, axes.fsdp, 0)
            w_up_z = _gather_fsdp(w_up_z, axes.fsdp, 0)
            down = _gather_fsdp(down, axes.fsdp, 2)
        B, S, d = x.shape
        hd_l = wv.shape[-1]                           # local value dim
        xi = jnp.einsum("bsd,de->bse", x, w_up_x)     # (B,S,di) replicated/model
        z = jnp.einsum("bsd,dhe->bshe", x, w_up_z)    # (B,S,nh,hd_l) TP
        xh = xi.reshape(B, S, nh, di // nh)
        q = jnp.einsum("bshd,hde->bshe", xh, wq).astype(jnp.float32)
        k = jnp.einsum("bshd,hde->bshe", xh, wk).astype(jnp.float32)
        v = jnp.einsum("bshd,hde->bshe", xh, wv).astype(jnp.float32)  # e local
        xif = xi.astype(jnp.float32)
        logi = xif @ w_i + b_i
        logf = jax.nn.log_sigmoid(xif @ w_f + b_f)

        Tc = min(chunk, S)
        if S % Tc:
            Tc = S
        nc = S // Tc
        state = (jnp.zeros((B, nh, di // nh, hd_l), jnp.float32),
                 jnp.zeros((B, nh, di // nh), jnp.float32),
                 jnp.full((B, nh), -1e30, jnp.float32),
                 jnp.zeros((B, nh), jnp.float32))
        if nc == 1:
            h, _ = _mlstm_chunk(q, k, v, logf, logi, state)
        else:
            def body(st, idx):
                sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * Tc, Tc, 1)
                h, st = _mlstm_chunk(sl(q), sl(k), sl(v), sl(logf), sl(logi), st)
                return st, h
            _, hs = jax.lax.scan(body, state, jnp.arange(nc))
            h = hs.swapaxes(0, 1).reshape(B, S, nh, hd_l)
        h = h * mh_norm                               # (B,S,nh,hd_l)
        h = h * jax.nn.silu(z.astype(jnp.float32))
        out = jnp.einsum("bshe,hed->bsd", h.astype(x.dtype), down)
        out = jax.lax.psum(out, m)                    # the ONE TP collective
        # name the psum result so the remat policy can SAVE it — otherwise
        # the backward replays the collective (EXPERIMENTS.md §Perf)
        from jax.ad_checkpoint import checkpoint_name
        return checkpoint_name(out, "tp_out")

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(bspec,
                  P(f, None),                # w_up_x
                  P(f, None, mz),            # w_up_z (d, nh, hd)
                  P(None, None, None),       # wq
                  P(None, None, None),       # wk
                  P(None, None, mz),         # wv (value dim TP)
                  P(None, None), P(None, None), P(None), P(None),
                  P(None, mz),               # mh_norm (nh, hd)
                  P(None, mz, f)),           # down_proj (nh, hd, d)
        out_specs=bspec, check_vma=False,
    )(x, p["w_up_x"], p["w_up_z"], p["wq"], p["wk"], p["wv"],
      p["w_i"], p["w_f"], p["b_i"], p["b_f"], p["mh_norm"], p["down_proj"])


def slstm_block_sharded(x, p, cfg: ModelConfig, *, mesh, axes,
                        batch_sharded: bool, fsdp: bool):
    """sLSTM with a collective-free time loop: batch over ``data``, weights
    replicated over ``model`` (the recurrence is tiny — d^2 work per step);
    FSDP gather of the input matrix at entry."""
    from jax.sharding import PartitionSpec as P
    bspec = P(axes.data, None, None) if batch_sharded else P(None, None, None)
    f = axes.fsdp if fsdp else None
    nh = max(cfg.n_heads, 1)

    def local(x, W, R, b):
        from repro.models.layers import bf16_grad_barrier
        x = bf16_grad_barrier(x)
        if fsdp:
            W = _gather_fsdp(W, axes.fsdp, 0)
        B, S, d = x.shape
        wx = jnp.einsum("bsd,de->bse", x, W).astype(jnp.float32)
        st = (jnp.zeros((B, d), jnp.float32), jnp.zeros((B, d), jnp.float32),
              jnp.zeros((B, d), jnp.float32), jnp.full((B, d), -1e30, jnp.float32))
        p_loc = {"R": R, "b": b}

        def body(st, wx_t):
            st = _slstm_step(p_loc, d, nh, st, wx_t)
            return st, st[0]

        _, hs = jax.lax.scan(body, st, wx.swapaxes(0, 1))
        return hs.swapaxes(0, 1).astype(x.dtype)

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(bspec, P(f, None), P(None, None, None), P(None)),
        out_specs=bspec, check_vma=False,
    )(x, p["W"], p["R"], p["b"])
