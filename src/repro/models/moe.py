"""Expert-parallel Mixture-of-Experts FFN.

TPU-native design (DESIGN §2, §5):

* Activations are replicated across the ``model`` axis (Megatron convention),
  so no all-to-all is needed for dispatch: each model shard owns
  ``E_local = E / model_ways`` experts, processes only the tokens routed to
  *its* experts, and the per-token combine is a single ``psum`` over
  ``model`` — the same collective the dense TP MLP already pays.
* Expert weights are additionally FSDP-sharded over the ``data`` axis and
  ``all_gather``-ed per layer (ZeRO-3); the gather transposes to a
  reduce-scatter of gradients.
* Dispatch avoids TPU scatter of activations: we scatter token *indices*
  into an (E_local, capacity) slot table, then gather activations — the
  scatter moves 4-byte ints, the bulk data movement is dense gathers.
* Tokens are processed in chunks (scan) to bound the dispatch buffers.

Capacity semantics match Spark-era MoE practice (and GShard): per chunk,
each expert accepts at most ``capacity_factor * chunk * top_k / E`` tokens;
overflow tokens are dropped (their residual passes through).  The router
aux loss is the standard load-balance loss.

Experts are padded to a multiple of 16 (the production ``model`` axis size)
when E >= 16, with padded router columns masked to -inf (never routed).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import normal
from repro.sharding.axes import MeshAxes

EXPERT_PAD_MULTIPLE = 16
MOE_CHUNK = 8192            # tokens per dispatch chunk (per data shard)


def padded_experts(n_experts: int) -> int:
    if n_experts >= EXPERT_PAD_MULTIPLE:
        return -(-n_experts // EXPERT_PAD_MULTIPLE) * EXPERT_PAD_MULTIPLE
    return n_experts


def init_moe(key, cfg: ModelConfig, d: int) -> dict:
    E, Ep, fe = cfg.n_experts, padded_experts(cfg.n_experts), cfg.expert_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": normal(ks[0], (d, Ep), d ** -0.5, jnp.float32),
        "w_gate": normal(ks[1], (Ep, d, fe), d ** -0.5, dt),
        "w_in": normal(ks[2], (Ep, d, fe), d ** -0.5, dt),
        "w_out": normal(ks[3], (Ep, fe, d), fe ** -0.5, dt),
    }
    if Ep != E:  # zero padded experts; router columns masked at use
        mask = (jnp.arange(Ep) < E).astype(dt)
        for k in ("w_gate", "w_in", "w_out"):
            p[k] = p[k] * mask[:, None, None]
        p["router"] = p["router"] * mask[None, :].astype(jnp.float32)
    if cfg.n_shared_experts:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], d, fe * cfg.n_shared_experts, cfg.activation, dt)
    return p


def _expert_ffn(xb, wg, wi, wo, activation: str):
    """xb: (E_l, C, d) -> (E_l, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", xb, wi)
    if activation == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xb, wg)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _moe_local(x, router, wg, wi, wo, *, cfg: ModelConfig, axes: MeshAxes,
               fsdp: bool):
    """shard_map body.  x: (B_l, S, d) (replicated over model);
    wg/wi/wo: (E_local, d[/fsdp], fe) local expert shards."""
    E = padded_experts(cfg.n_experts)
    k = cfg.top_k
    midx = jax.lax.axis_index(axes.model)
    nmodel = jax.lax.axis_size(axes.model)
    E_l = E // nmodel
    if fsdp:
        wg = jax.lax.all_gather(wg, axes.fsdp, axis=1, tiled=True)
        wi = jax.lax.all_gather(wi, axes.fsdp, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, axes.fsdp, axis=2, tiled=True)

    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ router)                       # (T, Ep)
    if E != cfg.n_experts:
        logits = jnp.where(jnp.arange(E) < cfg.n_experts, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                           # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), 1), 0)
    P_e = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(f_e * P_e)
    aux = jax.lax.pmean(aux, axes.data)

    chunk = min(MOE_CHUNK, T)
    if T % chunk:
        chunk = T
    nchunk = T // chunk
    C = max(8, int(cfg.capacity_factor * chunk * k / E))

    def one_chunk(carry, idx):
        start = idx * chunk
        xe = jax.lax.dynamic_slice_in_dim(xf, start, chunk, 0)       # (chunk, d)
        te = jax.lax.dynamic_slice_in_dim(top_e, start, chunk, 0)    # (chunk, k)
        tp = jax.lax.dynamic_slice_in_dim(top_p, start, chunk, 0)
        eid = te.reshape(-1)                                         # (chunk*k,)
        # position of each routed slot within its expert's queue
        onehot = jax.nn.one_hot(eid, E, dtype=jnp.int32)             # (chunk*k, E)
        pos = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(eid.size), eid]
        keep = pos < C
        # local experts only: [midx*E_l, (midx+1)*E_l)
        e_loc = eid - midx * E_l
        local = (e_loc >= 0) & (e_loc < E_l) & keep
        # scatter token indices into (E_l, C) slot table (ints only)
        slot_tok = jnp.zeros((E_l, C), jnp.int32)
        tok_of_slot = jnp.repeat(jnp.arange(chunk), k)
        slot_tok = slot_tok.at[
            jnp.where(local, e_loc, E_l), jnp.where(local, pos, 0)
        ].set(tok_of_slot + 1, mode="drop")                          # 0 = empty
        filled = slot_tok > 0
        xb = jnp.where(filled[..., None], xe[jnp.maximum(slot_tok - 1, 0)], 0)
        yb = _expert_ffn(xb.astype(x.dtype), wg, wi, wo, cfg.activation)
        yb = jnp.where(filled[..., None], yb, 0)
        # combine: for each (token, k) slot, read back its expert output
        y_slots = jnp.where(
            (local & keep)[:, None],
            yb[jnp.maximum(e_loc, 0), jnp.maximum(pos, 0)].astype(jnp.float32)
            * tp.reshape(-1)[:, None],
            0.0,
        )                                                            # (chunk*k, d)
        y = y_slots.reshape(chunk, k, d).sum(axis=1)
        dropped = jnp.sum((~keep).astype(jnp.float32)) / eid.size
        return carry, (y, dropped)

    _, (ys, dropped) = jax.lax.scan(one_chunk, 0, jnp.arange(nchunk))
    y = ys.reshape(T, d)
    y = jax.lax.psum(y, axes.model)                                  # combine experts
    dropped = jax.lax.pmean(jnp.mean(dropped), axes.data)
    return y.reshape(B, S, d).astype(x.dtype), aux, dropped


def moe_ffn(x, p, cfg: ModelConfig, axes: MeshAxes, *, mesh,
            batch_sharded: bool = True, fsdp: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss, dropped_frac).  x: (B, S, d) global."""
    bspec = P(axes.data) if batch_sharded else P(None)
    xspec = P(*bspec, None, None) if batch_sharded else P(None, None, None)
    fax = axes.fsdp if fsdp else None
    body = functools.partial(_moe_local, cfg=cfg, axes=axes, fsdp=fsdp)
    y, aux, dropped = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            xspec,
            P(None, None),                       # router replicated
            P(axes.model, fax, None),            # w_gate (E, d, fe)
            P(axes.model, fax, None),            # w_in
            P(axes.model, None, fax),            # w_out (E, fe, d)
        ),
        out_specs=(xspec, P(), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_in"], p["w_out"])
    if cfg.n_shared_experts:
        from repro.models.layers import mlp
        y = y + mlp(x, p["shared"], cfg.activation)
    return y, aux, dropped
