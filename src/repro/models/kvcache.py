"""Cache construction: shapes/dtypes for every block kind.

``init_cache`` builds zeros (runtime); ``cache_struct`` builds
ShapeDtypeStructs (dry-run) — same layout either way.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import block_period


def _block_cache_shapes(cfg: ModelConfig, kind: str, B: int, W: int,
                        cross: bool) -> Dict[str, Any]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    kvdt = jnp.dtype(cfg.dtype)
    if kind == "attn":
        if cfg.kv_dtype == "int8":
            # quantized cache: int8 payload + per-(slot, head) bf16 scales —
            # halves the decode HBM-read term (EXPERIMENTS.md §Perf)
            out = {
                "k": ((B, W, nkv, hd), jnp.int8),
                "v": ((B, W, nkv, hd), jnp.int8),
                "k_scale": ((B, W, nkv, 1), jnp.bfloat16),
                "v_scale": ((B, W, nkv, 1), jnp.bfloat16),
            }
        else:
            out = {
                "k": ((B, W, nkv, hd), kvdt),
                "v": ((B, W, nkv, hd), kvdt),
            }
        if cross:
            out["enc_k"] = ((B, cfg.n_frames, nkv, hd), kvdt)
            out["enc_v"] = ((B, cfg.n_frames, nkv, hd), kvdt)
        return out
    if kind == "mamba":
        return {
            "h": ((B, di, cfg.ssm_d_state), jnp.float32),
            "conv": ((B, cfg.ssm_d_conv - 1, di), jnp.float32),
        }
    if kind == "mlstm":
        hdm = di // nh
        return {
            "C": ((B, nh, hdm, hdm), jnp.float32),
            "n": ((B, nh, hdm), jnp.float32),
            "m": ((B, nh), jnp.float32),
            "F": ((B, nh), jnp.float32),
        }
    if kind == "slstm":
        return {k: ((B, d), jnp.float32) for k in ("h", "c", "n", "m")}
    raise ValueError(kind)


def cache_layout(cfg: ModelConfig, batch: int, seq_len: int):
    """{'pos{j}': {name: (shape, dtype)}} with stacked leading period dim."""
    p = block_period(cfg)
    nper = cfg.n_layers // p
    W = cfg.sliding_window or seq_len
    W = min(W, seq_len)
    out = {}
    for j, (kind, _moe) in enumerate(cfg.layer_pattern()[:p]):
        shapes = _block_cache_shapes(cfg, kind, batch, W, cfg.is_enc_dec)
        out[f"pos{j}"] = {k: ((nper,) + s, dt) for k, (s, dt) in shapes.items()}
    return out


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    lay = cache_layout(cfg, batch, seq_len)

    def make(name, shape, dt):
        if name == "m":  # stabilizer states start at -inf-ish
            return jnp.full(shape, -1e30, dt)
        return jnp.zeros(shape, dt)

    return {
        pj: {k: make(k, s, dt) for k, (s, dt) in sub.items()}
        for pj, sub in lay.items()
    }


def grow_cache(cache, cfg: ModelConfig, batch: int, total_len: int):
    """Re-seat a prefill cache (W = prompt_len) into a larger circular
    buffer sized for ``total_len`` (prompt + generation)."""
    big = init_cache(cfg, batch, total_len)
    out = {}
    for pj, sub in big.items():
        out[pj] = {}
        for k, dv in sub.items():
            sv = cache[pj][k]
            if dv.shape == sv.shape:
                out[pj][k] = sv
            elif k in ("k", "v", "k_scale", "v_scale") and dv.shape[2] >= sv.shape[2]:
                out[pj][k] = jax.lax.dynamic_update_slice_in_dim(
                    dv, sv.astype(dv.dtype), 0, axis=2)
            else:  # recurrent states carry over unchanged
                out[pj][k] = sv
    return out


def cache_struct(cfg: ModelConfig, batch: int, seq_len: int, shardings=None):
    lay = cache_layout(cfg, batch, seq_len)
    out = {}
    for pj, sub in lay.items():
        out[pj] = {}
        for k, (s, dt) in sub.items():
            sh = None if shardings is None else shardings[pj][k]
            out[pj][k] = jax.ShapeDtypeStruct(s, dt, sharding=sh)
    return out
