"""GQA attention: chunked-causal (train/prefill), cross, and cached decode.

Layout conventions
------------------
Weights keep a FLAT query-head dim ``nh`` (padded so ``nh = n_kv * G``),
which shards cleanly on the mesh ``model`` axis for every assigned arch
(nh in {16, 32, 48, 64} — all divisible by 16); queries are reshaped to the
grouped ``(n_kv, G)`` form only inside the attention math (DESIGN §5).

  wq: (d, nh, hd)           q: (B, S, nh, hd) -> (B, S, n_kv, G, hd)
  wk, wv: (d, n_kv, hd)     k, v: (B, S, n_kv, hd)   (= the KV cache entries)
  wo: (nh, hd, d)

llama3.2 pads 24 -> 32 q heads; padded head slices are zero in wq AND wo, so
the computed function is exactly the unpadded model's.

Memory: scores are never materialized for the full (S, S) square — queries
are processed in chunks of ``q_chunk`` via ``lax.map``, keys stay whole and
are masked (causal and/or sliding window).  Softmax in fp32.

Decode uses a circular KV cache of ``W`` slots (W = seq_len for full
attention, W = sliding_window for SWA); RoPE is applied to K at write time so
cached keys carry their absolute positions.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import normal, rope

NEG_INF = -1e30


TP_WAYS = 16    # production mesh `model` axis size — head padding target


def padded_heads(cfg: ModelConfig) -> Tuple[int, int]:
    """(nh_padded, G) with nh_padded = n_kv * G.

    G is bumped until nh_padded divides the production TP width, so the flat
    head dim always shards 16 ways (llama3.2: 24 -> 32 heads; padded head
    slices are zero in wq and wo, so the function is the unpadded model's).
    Without the bump, attention weights would replicate across the model
    axis and every shard would compute all heads — 16x redundant FLOPs.
    """
    G = -(-cfg.n_heads // cfg.n_kv_heads)
    if cfg.n_heads > TP_WAYS:
        while (cfg.n_kv_heads * G) % TP_WAYS:
            G += 1
    return cfg.n_kv_heads * G, G


def init_attn(key, cfg: ModelConfig, d: int, cross: bool = False) -> dict:
    nkv, hd = cfg.n_kv_heads, cfg.hd
    nhp, G = padded_heads(cfg)
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    sc = d ** -0.5
    wq = normal(ks[0], (d, nhp, hd), sc, dt)
    wo = normal(ks[3], (nhp, hd, d), (nhp * hd) ** -0.5, dt)
    if nhp != cfg.n_heads:
        # Zero the padded tail heads.  Flat head n maps to kv group n // G, so
        # the active 24 heads of llama3.2 spread 4-per-group over 6 kv groups
        # (instead of 3-per-group over 8) — an isomorphic parameterization for
        # from-scratch training; padded heads contribute exactly zero.
        mask = (jnp.arange(nhp) < cfg.n_heads).astype(dt)
        wq = wq * mask[None, :, None]
        wo = wo * mask[:, None, None]
    return {
        "wq": wq,
        "wk": normal(ks[1], (d, nkv, hd), sc, dt),
        "wv": normal(ks[2], (d, nkv, hd), sc, dt),
        "wo": wo,
    }


def _group(q, nkv: int):
    """(B,S,nh,hd) -> (B,S,nkv,G,hd)."""
    B, S, nh, hd = q.shape
    return q.reshape(B, S, nkv, nh // nkv, hd)


def _flat(o):
    """(B,S,nkv,G,hd) -> (B,S,nh,hd)."""
    B, S, nkv, G, hd = o.shape
    return o.reshape(B, S, nkv * G, hd)


def project_qkv(x, p, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
    if cfg.pos_embedding == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return _group(q, cfg.n_kv_heads), k, v


def _attend_chunk(qc, k, v, qpos, kpos, *, causal: bool, window: int):
    """qc: (B,C,nkv,G,hd); k,v: (B,S,nkv,hd); returns (B,C,nkv,G,hd)."""
    hd = qc.shape[-1]
    s = jnp.einsum("bckgh,bskh->bkgcs", qc, k).astype(jnp.float32) * (hd ** -0.5)
    mask = None
    if causal:
        mask = kpos[None, :] <= qpos[:, None]                       # (C,S)
    if window:
        w = kpos[None, :] > (qpos[:, None] - window)
        mask = w if mask is None else (mask & w)
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgcs,bskh->bckgh", a.astype(v.dtype), v)


def attention(q, k, v, *, causal: bool, window: int = 0,
              q_offset: int = 0, q_chunk: int = 1024) -> jnp.ndarray:
    """Chunked attention.  q: (B,S,nkv,G,hd); k/v: (B,Sk,nkv,hd).
    Returns flat (B,S,nh,hd)."""
    B, S = q.shape[:2]
    Sk = k.shape[1]
    kpos = jnp.arange(Sk)
    C = min(q_chunk, S)
    if S % C:
        C = S  # fall back to single chunk for odd sizes (smoke tests)
    nc = S // C
    if nc == 1:
        qpos = q_offset + jnp.arange(S)
        return _flat(_attend_chunk(q, k, v, qpos, kpos, causal=causal, window=window))
    qr = q.reshape(B, nc, C, *q.shape[2:]).swapaxes(0, 1)           # (nc,B,C,...)

    # remat each chunk: without this, the backward pass saves every chunk's
    # fp32 softmax weights and broadcast masks simultaneously (~S^2 fp32 per
    # layer — tens of GB at 4k x 64k tokens/device); with it, peak attention
    # memory is ONE chunk's scores (flash-attention-style recompute).
    @jax.checkpoint
    def one(args):
        i, qc = args
        qpos = q_offset + i * C + jnp.arange(C)
        return _attend_chunk(qc, k, v, qpos, kpos, causal=causal, window=window)

    out = jax.lax.map(one, (jnp.arange(nc), qr))                    # (nc,B,C,...)
    return _flat(out.swapaxes(0, 1).reshape(B, S, *q.shape[2:]))


def attn_block(x, p, cfg: ModelConfig, positions, *, window: int = 0,
               q_chunk: int = 1024):
    """Self-attention over a full sequence (train / prefill).

    Returns (out, (k, v)) — k/v are the cache entries (RoPE already applied).
    """
    q, k, v = project_qkv(x, p, cfg, positions)
    o = attention(q, k, v, causal=True, window=window, q_chunk=q_chunk)
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"]), (k, v)


def cross_attn_block(x, p, cfg: ModelConfig, enc_k, enc_v, *, q_chunk: int = 1024):
    """Cross-attention: queries from decoder x, keys/values precomputed."""
    q = _group(jnp.einsum("bsd,dnh->bsnh", x, p["wq"]), cfg.n_kv_heads)  # no RoPE
    o = attention(q, enc_k, enc_v, causal=False, q_chunk=q_chunk)
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"])


def project_enc_kv(enc_out, p):
    k = jnp.einsum("bsd,dnh->bsnh", enc_out, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", enc_out, p["wv"])
    return k, v


# -------------------------------------------------------------- int8 cache
def quantize_kv(x):
    """(val, scale): per-(pos, head) absmax int8 quantization.
    x: (B,S,nkv,hd) -> (int8 same shape, bf16 (B,S,nkv,1))."""
    scale = jnp.max(jnp.abs(x).astype(jnp.float32), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def dequantize_kv(q, scale, dtype):
    return q.astype(jnp.float32).astype(dtype) * scale.astype(dtype)


# ------------------------------------------------------------------- decode
def _decode_positions(pos, B):
    """Normalize decode position(s): scalar -> (B,), keeps (B,) as-is.
    Per-slot positions enable continuous batching (requests at different
    generation offsets share one decode program)."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (B,))
    return pos


def _cache_write(cache, val, slots):
    """Per-batch circular write: cache (B,W,...), val (B,1,...), slots (B,)."""
    B = cache.shape[0]
    return cache.at[jnp.arange(B), slots].set(
        val[:, 0].astype(cache.dtype))


def decode_attn_block(x1, p, cfg: ModelConfig, cache_k, cache_v, pos, *,
                      window_slots: int):
    """One-token decode against a circular KV cache.

    x1: (B,1,d); cache_k/v: (B,W,nkv,hd); pos: scalar int32 OR (B,) int32 —
    absolute position of each sequence's new token (vector positions allow
    continuous batching).  The new entry overwrites the oldest slot, keeping
    exactly the last W positions — full attention is the W=seq_len case.
    Returns (out, new_cache_k, new_cache_v).
    """
    B = x1.shape[0]
    posv = _decode_positions(pos, B)[:, None]               # (B,1)
    q = jnp.einsum("bsd,dnh->bsnh", x1, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x1, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x1, p["wv"])
    if cfg.pos_embedding == "rope":
        q = rope(q, posv, cfg.rope_theta)
        k = rope(k, posv, cfg.rope_theta)
    q = _group(q, cfg.n_kv_heads)
    slots = jnp.mod(posv[:, 0], window_slots)               # (B,)
    cache_k = _cache_write(cache_k, k, slots)
    cache_v = _cache_write(cache_v, v, slots)
    hd = q.shape[-1]
    s = jnp.einsum("bckgh,bskh->bkgcs", q, cache_k).astype(jnp.float32) * (hd ** -0.5)
    # validity: pos+1 tokens exist; before wraparound only slots <= pos are
    # live (all slots are live once pos >= W, and arange(W) <= pos is then
    # all-true, so one expression covers both phases)
    valid = jnp.arange(window_slots)[None] <= posv          # (B,W)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgcs,bskh->bckgh", a.astype(cache_v.dtype), cache_v)
    out = jnp.einsum("bsnh,nhd->bsd", _flat(o), p["wo"])
    return out, cache_k, cache_v


def decode_cross_attn_block(x1, p, enc_k, enc_v):
    nkv = enc_k.shape[2]
    q = _group(jnp.einsum("bsd,dnh->bsnh", x1, p["wq"]), nkv)
    hd = q.shape[-1]
    s = jnp.einsum("bckgh,bskh->bkgcs", q, enc_k).astype(jnp.float32) * (hd ** -0.5)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgcs,bskh->bckgh", a.astype(enc_v.dtype), enc_v)
    return jnp.einsum("bsnh,nhd->bsd", _flat(o), p["wo"])


def decode_attn_block_q(x1, p, cfg: ModelConfig, cache, pos, *,
                        window_slots: int):
    """int8-cache variant of decode_attn_block.  cache: dict with int8 k/v
    and bf16 k_scale/v_scale; dequantization happens after the (int8 + small
    scales) HBM read — the decode memory term halves (EXPERIMENTS.md §Perf).
    Returns (out, new_cache_dict)."""
    B = x1.shape[0]
    posv = _decode_positions(pos, B)[:, None]
    q = jnp.einsum("bsd,dnh->bsnh", x1, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x1, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x1, p["wv"])
    if cfg.pos_embedding == "rope":
        q = rope(q, posv, cfg.rope_theta)
        k = rope(k, posv, cfg.rope_theta)
    q = _group(q, cfg.n_kv_heads)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    slots = jnp.mod(posv[:, 0], window_slots)
    cache = dict(cache,
                 k=_cache_write(cache["k"], kq, slots),
                 v=_cache_write(cache["v"], vq, slots),
                 k_scale=_cache_write(cache["k_scale"], ks, slots),
                 v_scale=_cache_write(cache["v_scale"], vs, slots))
    kd = dequantize_kv(cache["k"], cache["k_scale"], x1.dtype)
    vd = dequantize_kv(cache["v"], cache["v_scale"], x1.dtype)
    hd = q.shape[-1]
    s = jnp.einsum("bckgh,bskh->bkgcs", q, kd).astype(jnp.float32) * (hd ** -0.5)
    valid = jnp.arange(window_slots)[None] <= posv
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgcs,bskh->bckgh", a.astype(vd.dtype), vd)
    out = jnp.einsum("bsnh,nhd->bsd", _flat(o), p["wo"])
    return out, cache
