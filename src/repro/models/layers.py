"""Shared neural building blocks (pure JAX, framework-free).

Parameters are plain nested dicts of ``jnp.ndarray``.  All matmuls run in the
config compute dtype; norms, softmax and recurrent states run in fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------- utils
def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pad_vocab(v: int, multiple: int = 256) -> int:
    return -(-v // multiple) * multiple


def normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


@jax.custom_vjp
def bf16_grad_barrier(x):
    """Identity that casts the COTANGENT to bf16 (then back to x's dtype).

    Placed at block outputs: backward-pass activation cotangents cross the
    tensor-parallel psum (and the remat residual stack) in bf16 instead of
    fp32 — halving backward collective bytes and saved-residual memory
    (EXPERIMENTS.md §Perf; standard mixed-precision practice: gradients
    tolerate bf16 rounding at block granularity).
    """
    return x


def _bgb_fwd(x):
    return x, jnp.zeros((0,), x.dtype)          # carry the primal dtype only


def _bgb_bwd(res, g):
    # the cotangent of a bf16 primal IS bf16 — upstream fp32 promotions
    # (norm/gate internals) are rounded off right here, before any
    # collective or residual-stack store sees them
    tgt = jnp.bfloat16 if res.dtype == jnp.bfloat16 else res.dtype
    return (g.astype(tgt),)


bf16_grad_barrier.defvjp(_bgb_fwd, _bgb_bwd)


# --------------------------------------------------------------------- norms
def norm(x: jnp.ndarray, p: dict, kind: str, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm / LayerNorm in fp32, cast back to input dtype."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = xf * p["scale"].astype(jnp.float32)
    if "bias" in p:
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(dt)


def init_norm(d: int, kind: str):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


# ---------------------------------------------------------------------- rope
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Interleaved-pair RoPE: pairs are (2i, 2i+1) along the head dim.

    The interleaved layout keeps each rotation pair adjacent, so the head dim
    can be sharded in any even-sized chunks without splitting pairs (DESIGN §5).

    x: (..., S, ..., hd) with positions broadcastable to x's S position —
    we require x shaped (B, S, *heads, hd) and positions (B, S) or (S,).
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)   # (half,)
    ang = positions.astype(jnp.float32)[..., None] * freqs              # (B?,S,half)
    # insert singleton head axes between S and hd: x is (B, S, *heads, hd);
    # works for both (S,) and per-batch (B,S) position arrays
    for _ in range(x.ndim - 3):
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xf = x.astype(jnp.float32)
    x0 = xf[..., 0::2]
    x1 = xf[..., 1::2]
    r0 = x0 * cos - x1 * sin
    r1 = x0 * sin + x1 * cos
    out = jnp.stack([r0, r1], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- mlp
def mlp(x: jnp.ndarray, p: dict, activation: str) -> jnp.ndarray:
    """SwiGLU or GeLU MLP.  Weights: w_in (d,f), w_out (f,d), [w_gate (d,f)]."""
    h = jnp.einsum("...d,df->...f", x, p["w_in"])
    if activation == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    return jnp.einsum("...f,fd->...d", h, p["w_out"])


def init_mlp(key, d: int, f: int, activation: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_in": normal(ks[0], (d, f), d ** -0.5, dtype),
        "w_out": normal(ks[1], (f, d), f ** -0.5, dtype),
    }
    if activation == "swiglu":
        p["w_gate"] = normal(ks[2], (d, f), d ** -0.5, dtype)
    return p
