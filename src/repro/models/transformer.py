"""Architecture assembly: init, full-sequence forward, prefill, decode.

The layer stack is organized as *periods*: the per-layer pattern
(attention / mamba / mLSTM / sLSTM, MoE or dense FFN) repeats with period
``p`` (jamba: 8, xlstm: 8, uniform archs: 1).  Parameters for position ``j``
of the period are stacked across the ``n_layers/p`` repetitions and the stack
is traversed with one ``lax.scan`` — keeping the HLO size O(period), not
O(n_layers), which is what makes 80 production-mesh dry-run compiles
tractable (DESIGN §5).

Caches are pytrees mirroring the same (period-position -> stacked) layout:
  attn:  {'k','v'}: (nper, B, W, n_kv, hd)   circular, W = window slots
  mamba: {'h': (nper,B,di,N), 'conv': (nper,B,dc-1,di)}
  mlstm: {'C','n','m','F'}; slstm: {'h','c','n','m'}
  enc-dec adds {'enc': {'k','v'}: (nper, B, frames, n_kv, hd)}.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace as dc_replace
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (bf16_grad_barrier, init_mlp, init_norm, mlp,
                                 norm, normal, pad_vocab)
from repro.sharding.axes import MeshAxes


# ----------------------------------------------------------------- context
@dataclass(frozen=True)
class Context:
    """Everything a block needs besides params/activations."""
    mesh: Any = None
    axes: MeshAxes = MeshAxes()
    mode: str = "full"              # full | decode
    batch_sharded: bool = True
    fsdp: bool = False
    q_chunk: int = 1024
    window: int = 0                 # SWA window for attn layers (0 = full)
    pos: Any = None                 # decode: scalar absolute position
    positions: Any = None           # full: (S,) absolute positions
    collect_cache: bool = False     # full mode: emit cache entries (prefill)

    def shard_acts(self, x):
        """Anchor activations to (batch over data, replicated, replicated).

        Without these anchors XLA's sharding propagation can legally choose a
        batch-replicated layout for intermediates (observed: full-global-batch
        fp32 attention scores per device); constraining the residual stream at
        block boundaries pins the data-parallel layout everywhere between.
        """
        if not self.batch_sharded or self.mesh is None:
            return x
        spec = jax.sharding.PartitionSpec(
            tuple(self.axes.data), *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, spec)


def block_period(cfg: ModelConfig) -> int:
    pat = cfg.layer_pattern()
    n = len(pat)
    for p in range(1, n + 1):
        if n % p == 0 and all(pat[i] == pat[i % p] for i in range(n)):
            return p
    return n


# ------------------------------------------------------------------- init
def _init_block(key, cfg: ModelConfig, kind: str, moe: bool, cross: bool) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": init_norm(d, cfg.norm)}
    if kind == "attn":
        p["mixer"] = attn_lib.init_attn(ks[0], cfg, d)
    elif kind == "mamba":
        p["mixer"] = ssm_lib.init_mamba(ks[0], cfg, d)
    elif kind == "mlstm":
        p["mixer"] = ssm_lib.init_mlstm(ks[0], cfg, d)
    elif kind == "slstm":
        p["mixer"] = ssm_lib.init_slstm(ks[0], cfg, d)
    else:
        raise ValueError(kind)
    if cross and kind == "attn":
        p["xnorm"] = init_norm(d, cfg.norm)
        p["xattn"] = attn_lib.init_attn(ks[1], cfg, d, cross=True)
    if moe:
        p["norm2"] = init_norm(d, cfg.norm)
        p["ffn"] = moe_lib.init_moe(ks[2], cfg, d)
    elif cfg.d_ff > 0:
        p["norm2"] = init_norm(d, cfg.norm)
        p["ffn"] = init_mlp(ks[2], d, cfg.d_ff, cfg.activation, jnp.dtype(cfg.dtype))
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    Vp = pad_vocab(cfg.vocab_size)
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": normal(keys[0], (Vp, d), 0.02, dt),
        "final_norm": init_norm(d, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal(keys[1], (Vp, d), 0.02, dt)
    if cfg.pos_embedding == "learned":
        params["pos_embed"] = normal(keys[2], (max(cfg.n_frames, 4096), d), 0.02, dt)
    if cfg.n_patches or cfg.is_enc_dec:
        params["frontend_proj"] = normal(keys[3], (d, d), d ** -0.5, dt)

    p = block_period(cfg)
    nper = cfg.n_layers // p
    pat = cfg.layer_pattern()[:p]
    cross = cfg.is_enc_dec
    layers = {}
    for j, (kind, moe) in enumerate(pat):
        jk = jax.random.fold_in(keys[4], j)
        layers[f"pos{j}"] = jax.vmap(
            lambda k: _init_block(k, cfg, kind, moe, cross)
        )(jax.random.split(jk, nper))
    params["layers"] = layers

    if cfg.is_enc_dec:
        enc_cfg = cfg  # same dims for whisper
        params["enc"] = {
            "layers": jax.vmap(
                lambda k: _init_block(k, enc_cfg, "attn", False, False)
            )(jax.random.split(keys[5], cfg.n_enc_layers)),
            "norm": init_norm(d, cfg.norm),
        }
    return params


# ------------------------------------------------------------------ embed
def embed_tokens(params, cfg: ModelConfig, tokens):
    e = jnp.take(params["embed"], tokens, axis=0)
    return e.astype(jnp.dtype(cfg.dtype))


def unembed(params, cfg: ModelConfig, h):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", h, table).astype(jnp.float32)
    Vp = table.shape[0]
    if Vp != cfg.vocab_size:
        logits = jnp.where(jnp.arange(Vp) < cfg.vocab_size, logits, -1e30)
    return logits


def add_positions(params, cfg: ModelConfig, x, positions):
    if cfg.pos_embedding == "learned":
        tab = params["pos_embed"]
        idx = jnp.mod(positions, tab.shape[0])
        x = x + jnp.take(tab, idx, axis=0).astype(x.dtype)
    return x


# ----------------------------------------------------------------- blocks
def _apply_ffn(x, p, cfg: ModelConfig, moe: bool, ctx: Context):
    """Returns (y, aux)."""
    if "ffn" not in p:
        return jnp.zeros_like(x), jnp.float32(0.0)
    h = norm(x, p["norm2"], cfg.norm)
    if moe:
        y, aux, _dropped = moe_lib.moe_ffn(
            h, p["ffn"], cfg, ctx.axes, mesh=ctx.mesh,
            batch_sharded=ctx.batch_sharded, fsdp=ctx.fsdp)
        return y, aux * cfg.router_aux_coef
    return mlp(h, p["ffn"], cfg.activation), jnp.float32(0.0)


def apply_block(x, p, cfg: ModelConfig, kind: str, moe: bool, ctx: Context,
                cache=None, enc_out=None):
    """Returns (x, new_cache, aux)."""
    h = norm(x, p["norm1"], cfg.norm)
    newc = None
    if kind == "attn":
        if ctx.mode == "decode":
            if cfg.kv_dtype == "int8":
                a, newc = attn_lib.decode_attn_block_q(
                    h, p["mixer"], cfg, cache, ctx.pos,
                    window_slots=cache["k"].shape[1])
            else:
                a, ck, cv = attn_lib.decode_attn_block(
                    h, p["mixer"], cfg, cache["k"], cache["v"], ctx.pos,
                    window_slots=cache["k"].shape[1])
                newc = dict(cache, k=ck, v=cv)
        else:
            a, (k, v) = attn_lib.attn_block(
                h, p["mixer"], cfg, ctx.positions,
                window=ctx.window, q_chunk=ctx.q_chunk)
            W = ctx.window or k.shape[1]
            if cfg.kv_dtype == "int8":
                kq, ks = attn_lib.quantize_kv(k[:, -W:])
                vq, vs = attn_lib.quantize_kv(v[:, -W:])
                newc = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            else:
                newc = {"k": k[:, -W:], "v": v[:, -W:]}
        x = x + a
        if "xattn" in p:
            hx = norm(x, p["xnorm"], cfg.norm)
            if ctx.mode == "decode":
                cx = attn_lib.decode_cross_attn_block(
                    hx, p["xattn"], cache["enc_k"], cache["enc_v"])
            else:
                ek, ev = attn_lib.project_enc_kv(enc_out, p["xattn"])
                cx = attn_lib.cross_attn_block(hx, p["xattn"], cfg, ek, ev,
                                               q_chunk=ctx.q_chunk)
                newc["enc_k"], newc["enc_v"] = ek, ev
            if ctx.mode == "decode":
                newc["enc_k"], newc["enc_v"] = cache["enc_k"], cache["enc_v"]
            x = x + cx
    elif kind == "mamba":
        if ctx.mode == "decode":
            a, st = ssm_lib.mamba_decode(h, p["mixer"], cfg, cache)
        else:
            a, st = ssm_lib.mamba_block(h, p["mixer"], cfg)
        newc = st
        x = x + a
    elif kind == "mlstm":
        if ctx.mode == "decode":
            a, st = ssm_lib.mlstm_decode(
                h, p["mixer"], cfg,
                (cache["C"], cache["n"], cache["m"], cache["F"]))
            newc = {"C": st[0], "n": st[1], "m": st[2], "F": st[3]}
        elif ctx.mesh is not None and not ctx.collect_cache:
            # explicit-layout SPMD variant (no cache output): kills the
            # per-chunk resharding collectives the auto-sharded form hits
            a = ssm_lib.mlstm_block_sharded(
                h, p["mixer"], cfg, mesh=ctx.mesh, axes=ctx.axes,
                batch_sharded=ctx.batch_sharded, fsdp=ctx.fsdp)
            newc = None
        else:
            a, st = ssm_lib.mlstm_block(h, p["mixer"], cfg)
            newc = {"C": st[0], "n": st[1], "m": st[2], "F": st[3]}
        x = x + a
    elif kind == "slstm":
        if ctx.mode == "decode":
            a, st = ssm_lib.slstm_decode(
                h, p["mixer"], cfg,
                (cache["h"], cache["c"], cache["n"], cache["m"]))
            newc = {"h": st[0], "c": st[1], "n": st[2], "m": st[3]}
        elif ctx.mesh is not None and not ctx.collect_cache:
            a = ssm_lib.slstm_block_sharded(
                h, p["mixer"], cfg, mesh=ctx.mesh, axes=ctx.axes,
                batch_sharded=ctx.batch_sharded, fsdp=ctx.fsdp)
            newc = None
        else:
            a, st = ssm_lib.slstm_block(h, p["mixer"], cfg)
            newc = {"h": st[0], "c": st[1], "n": st[2], "m": st[3]}
        x = x + a
    else:
        raise ValueError(kind)

    y, aux = _apply_ffn(x, p, cfg, moe, ctx)
    return x + y, newc, aux


# ------------------------------------------------------------------ trunk
def _scan_layers(x, params, cfg: ModelConfig, ctx: Context, cache=None,
                 enc_out=None, collect_cache=False):
    """Scan the period-structured decoder stack.

    Returns (x, new_cache_or_None, aux_sum)."""
    p = block_period(cfg)
    pat = cfg.layer_pattern()[:p]
    layer_params = tuple(params["layers"][f"pos{j}"] for j in range(p))
    cache_xs = tuple(cache[f"pos{j}"] for j in range(p)) if cache is not None else None

    def body(carry, xs):
        x, aux = carry
        pp = xs[0]
        cc = xs[1] if cache_xs is not None else (None,) * p
        newcs = []
        for j, (kind, moe) in enumerate(pat):
            x = ctx.shard_acts(x)
            if ctx.mode == "full":
                # pin backward cotangents to bf16 at block boundaries: the
                # norm backward otherwise promotes the residual cotangent
                # chain to fp32, doubling TP-psum bytes and remat residuals
                x = bf16_grad_barrier(x)
            x, nc, a = apply_block(x, pp[j], cfg, kind, moe, ctx,
                                   cache=cc[j], enc_out=enc_out)
            newcs.append(nc)
            aux = aux + a
        x = ctx.shard_acts(x)
        ys = tuple(newcs) if (collect_cache or cache_xs is not None) else None
        return (x, aux), ys

    # remat policy: recompute everything EXCEPT named TP-psum outputs —
    # replaying a collective costs ICI twice, saving it costs bf16 bytes
    body_fn = jax.checkpoint(
        body, policy=jax.checkpoint_policies.save_only_these_names("tp_out")
    ) if ctx.mode == "full" else body
    xs = (layer_params,) if cache_xs is None else (layer_params, cache_xs)
    (x, aux), ys = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), xs)
    new_cache = None
    if ys is not None:
        new_cache = {f"pos{j}": ys[j] for j in range(p)}
    return x, new_cache, aux


def encode(params, cfg: ModelConfig, frames, ctx: Context):
    """Whisper-style encoder over stubbed frame embeddings (B,F,d)."""
    x = jnp.einsum("bfd,de->bfe", frames.astype(jnp.dtype(cfg.dtype)),
                   params["frontend_proj"])
    x = add_positions(params, cfg, x, jnp.arange(x.shape[1]))
    ectx = dc_replace(ctx, window=0)

    def body(carry, pp):
        carry = ectx.shard_acts(carry)
        h = norm(carry, pp["norm1"], cfg.norm)
        q, k, v = attn_lib.project_qkv(h, pp["mixer"], cfg, jnp.arange(h.shape[1]))
        a = attn_lib.attention(q, k, v, causal=False, q_chunk=ectx.q_chunk)
        a = jnp.einsum("bsnh,nhd->bsd", a, pp["mixer"]["wo"])
        x2 = carry + a
        h2 = norm(x2, pp["norm2"], cfg.norm)
        return x2 + mlp(h2, pp["ffn"], cfg.activation), None

    # remat policy: recompute everything EXCEPT named TP-psum outputs —
    # replaying a collective costs ICI twice, saving it costs bf16 bytes
    body_fn = jax.checkpoint(
        body, policy=jax.checkpoint_policies.save_only_these_names("tp_out")
    ) if ctx.mode == "full" else body
    x, _ = jax.lax.scan(body_fn, x, params["enc"]["layers"])
    return norm(x, params["enc"]["norm"], cfg.norm)


# -------------------------------------------------------------- public api
def build_inputs_embeds(params, cfg: ModelConfig, tokens, frontend=None):
    """tokens: (B, S_text).  VLM: prepend projected patch embeddings."""
    e = embed_tokens(params, cfg, tokens)
    if cfg.n_patches and frontend is not None:
        pe = jnp.einsum("bpd,de->bpe", frontend.astype(e.dtype),
                        params["frontend_proj"])
        e = jnp.concatenate([pe, e], axis=1)
    return e


def forward(params, cfg: ModelConfig, tokens, ctx: Context, *,
            frontend=None, collect_cache=False):
    """Full-sequence forward.  Returns (hidden (B,S,d), cache|None, aux).

    ``frontend``: VLM patch embeddings (B,P,d) or audio frames (B,F,d)."""
    enc_out = None
    if cfg.is_enc_dec:
        enc_out = encode(params, cfg, frontend, ctx)
        x = embed_tokens(params, cfg, tokens)
    else:
        x = build_inputs_embeds(params, cfg, tokens, frontend)
    S = x.shape[1]
    positions = jnp.arange(S)
    x = add_positions(params, cfg, x, positions)
    ctx = dc_replace(ctx, positions=positions, mode="full",
                     collect_cache=collect_cache)
    x, cache, aux = _scan_layers(x, params, cfg, ctx, enc_out=enc_out,
                                 collect_cache=collect_cache)
    x = norm(x, params["final_norm"], cfg.norm)
    return x, cache, aux


def prefill(params, cfg: ModelConfig, tokens, ctx: Context, *, frontend=None):
    """Process a prompt; return (last-token logits, cache, seq_len)."""
    h, cache, _aux = forward(params, cfg, tokens, ctx, frontend=frontend,
                             collect_cache=True)
    logits = unembed(params, cfg, h[:, -1:])
    return logits, cache


def decode_step(params, cfg: ModelConfig, token, cache, pos, ctx: Context):
    """One-token serve step.  token: (B,1) int32; pos: scalar OR (B,) int32
    absolute position of each sequence's token (vector positions enable
    continuous batching).  Returns (logits (B,1,V), new_cache)."""
    from repro.models.attention import _decode_positions
    x = embed_tokens(params, cfg, token)
    posn = _decode_positions(pos, token.shape[0])
    x = add_positions(params, cfg, x, posn[:, None])
    ctx = dc_replace(ctx, mode="decode", pos=pos)
    x, new_cache, _aux = _scan_layers(x, params, cfg, ctx, cache=cache)
    x = norm(x, params["final_norm"], cfg.norm)
    return unembed(params, cfg, x), new_cache
