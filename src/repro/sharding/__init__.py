from repro.sharding.axes import MeshAxes, axes_from_mesh, make_test_mesh
