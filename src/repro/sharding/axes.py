"""Mesh axis conventions.

Single-pod mesh: (data, model) = (16, 16).
Multi-pod mesh:  (pod, data, model) = (2, 16, 16).

``MeshAxes`` names the roles:
  * ``data``  — tuple of axes the batch shards over (('pod','data') multi-pod).
  * ``model`` — tensor-parallel axis.
  * ``fsdp``  — axis parameters/optimizer shard over (ZeRO); kept within a pod
    so the pod axis carries only gradient all-reduce traffic (DESIGN §8).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


@dataclass(frozen=True)
class MeshAxes:
    data: Tuple[str, ...] = ("data",)
    model: str = "model"
    fsdp: str = "data"

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(self.data) + (self.model,)


SINGLE_POD = MeshAxes(data=("data",), model="model", fsdp="data")
MULTI_POD = MeshAxes(data=("pod", "data"), model="model", fsdp="data")


def axes_from_mesh(mesh: Mesh) -> MeshAxes:
    return MULTI_POD if "pod" in mesh.axis_names else SINGLE_POD


def mesh_sizes(mesh: Mesh, axes: MeshAxes) -> Tuple[int, int]:
    """(total batch-sharding ways, model-parallel ways)."""
    d = 1
    for a in axes.data:
        d *= mesh.shape[a]
    return d, mesh.shape[axes.model]


def make_test_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh for CPU tests (1x1 by default)."""
    devs = jax.devices()[: data * model]
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
        devices=devs,
    )
