"""PartitionSpec rules for params, caches, and inputs.

The spec trees mirror ``models.transformer.init_params`` /
``models.kvcache.cache_layout`` exactly.  Tensor parallelism (axis
``model``) follows Megatron conventions — column-parallel up-projections,
row-parallel down-projections with an implicit all-reduce; ZeRO-style FSDP
shards the *other* big dim over the ``data`` axis (gathered per layer,
transposed to gradient reduce-scatters).  Dims that don't divide the mesh
axis fall back to replication (guarded by ``_ok``).

The paper's technique lives on the ``data`` axis: every train step is
"partition examples, compute local statistics (gradients), all-reduce" —
Spark's treeAggregate as an ICI collective (DESIGN §1).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models.attention import padded_heads
from repro.models.moe import padded_experts
from repro.models.transformer import block_period
from repro.sharding.axes import MeshAxes, mesh_sizes


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


class SpecBuilder:
    def __init__(self, cfg: ModelConfig, mesh: Mesh, axes: MeshAxes,
                 fsdp: bool):
        self.cfg = cfg
        self.mesh = mesh
        self.axes = axes
        self.model = axes.model
        self.fsdp = axes.fsdp if fsdp else None
        self.fsdp_enabled = fsdp

    def ok(self, dim: int, axis) -> Any:
        """axis if dim divides its mesh size, else None (replicate)."""
        if axis is None:
            return None
        return axis if dim % _axis_size(self.mesh, axis) == 0 else None

    # ------------------------------------------------------------- blocks
    def _attn_specs(self) -> dict:
        cfg = self.cfg
        nhp, _G = padded_heads(cfg)
        m, f = self.model, self.fsdp
        d = cfg.d_model
        kv_ax = self.ok(cfg.n_kv_heads, m)
        return {
            "wq": P(self.ok(d, f), self.ok(nhp, m), None),
            "wk": P(self.ok(d, f), kv_ax, None),
            "wv": P(self.ok(d, f), kv_ax, None),
            "wo": P(self.ok(nhp, m), None, self.ok(d, f)),
        }

    def _mlp_specs(self, d: int, ff: int) -> dict:
        m, f = self.model, self.fsdp
        sp = {
            "w_in": P(self.ok(d, f), self.ok(ff, m)),
            "w_out": P(self.ok(ff, m), self.ok(d, f)),
        }
        if self.cfg.activation == "swiglu":
            sp["w_gate"] = P(self.ok(d, f), self.ok(ff, m))
        return sp

    def _moe_specs(self) -> dict:
        cfg = self.cfg
        m, f = self.model, self.fsdp
        Ep, fe, d = padded_experts(cfg.n_experts), cfg.expert_ff, cfg.d_model
        e_ax = self.ok(Ep, m)
        sp = {
            "router": P(None, None),
            "w_gate": P(e_ax, self.ok(d, f), None),
            "w_in": P(e_ax, self.ok(d, f), None),
            "w_out": P(e_ax, None, self.ok(d, f)),
        }
        if cfg.n_shared_experts:
            sp["shared"] = self._mlp_specs(d, fe * cfg.n_shared_experts)
        return sp

    def _mamba_specs(self) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        di = cfg.ssm_expand * d
        m, f = self.model, self.fsdp
        di_ax = self.ok(di, m)
        return {
            "in_proj": P(self.ok(d, f), self.ok(2 * di, m)),
            "conv_w": P(None, di_ax),
            "x_proj": P(di_ax, None),
            "dt_proj": P(None, di_ax),
            "dt_bias": P(di_ax),
            "A_log": P(di_ax, None),
            "D": P(di_ax),
            "out_proj": P(di_ax, self.ok(d, f)),
        }

    def _mlstm_specs(self) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        di = cfg.ssm_expand * d
        nh = cfg.n_heads
        hd = di // nh
        m, f = self.model, self.fsdp
        hd_ax = self.ok(hd, m)                        # value dim is TP-sharded
        return {
            "w_up_x": P(self.ok(d, f), None),
            "w_up_z": P(self.ok(d, f), None, hd_ax),
            "wq": P(None, None, None),
            "wk": P(None, None, None),
            "wv": P(None, None, hd_ax),
            "w_i": P(None, None),
            "w_f": P(None, None),
            "b_i": P(None),
            "b_f": P(None),
            "mh_norm": P(None, hd_ax),
            "down_proj": P(None, hd_ax, self.ok(d, f)),
        }

    def _slstm_specs(self) -> dict:
        # sLSTM is sequential and tiny; replicate over model, FSDP the input mat
        d = self.cfg.d_model
        return {
            "W": P(self.ok(d, self.fsdp), None),
            "R": P(None, None, None),
            "b": P(None),
        }

    def _norm_specs(self) -> dict:
        sp = {"scale": P(None)}
        if self.cfg.norm == "layernorm":
            sp["bias"] = P(None)
        return sp

    def _block_specs(self, kind: str, moe: bool, cross: bool) -> dict:
        cfg = self.cfg
        sp: Dict[str, Any] = {"norm1": self._norm_specs()}
        if kind == "attn":
            sp["mixer"] = self._attn_specs()
        elif kind == "mamba":
            sp["mixer"] = self._mamba_specs()
        elif kind == "mlstm":
            sp["mixer"] = self._mlstm_specs()
        elif kind == "slstm":
            sp["mixer"] = self._slstm_specs()
        if cross and kind == "attn":
            sp["xnorm"] = self._norm_specs()
            sp["xattn"] = self._attn_specs()
        if moe:
            sp["norm2"] = self._norm_specs()
            sp["ffn"] = self._moe_specs()
        elif cfg.d_ff > 0:
            sp["norm2"] = self._norm_specs()
            sp["ffn"] = self._mlp_specs(cfg.d_model, cfg.d_ff)
        return sp

    # -------------------------------------------------------------- trees
    def param_specs(self) -> dict:
        cfg = self.cfg
        m, f = self.model, self.fsdp
        d = cfg.d_model
        from repro.models.layers import pad_vocab
        Vp = pad_vocab(cfg.vocab_size)
        specs: Dict[str, Any] = {
            "embed": P(self.ok(Vp, m), self.ok(d, f)),
            "final_norm": self._norm_specs(),
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = P(self.ok(Vp, m), self.ok(d, f))
        if cfg.pos_embedding == "learned":
            specs["pos_embed"] = P(None, None)
        if cfg.n_patches or cfg.is_enc_dec:
            specs["frontend_proj"] = P(self.ok(d, f), None)

        def stack(tree):
            return jax.tree.map(lambda s: P(None, *s), tree,
                                is_leaf=lambda x: isinstance(x, P))

        p = block_period(cfg)
        pat = cfg.layer_pattern()[:p]
        specs["layers"] = {
            f"pos{j}": stack(self._block_specs(kind, moe, cfg.is_enc_dec))
            for j, (kind, moe) in enumerate(pat)
        }
        if cfg.is_enc_dec:
            specs["enc"] = {
                "layers": stack(self._block_specs("attn", False, False)),
                "norm": self._norm_specs(),
            }
        return specs

    # -------------------------------------------------------------- cache
    def cache_specs(self, shape: InputShape) -> dict:
        """Specs mirroring kvcache.cache_layout.

        decode_32k: batch over data, seq/state over model.
        long_500k (B=1): seq/state over ALL axes (flash-decoding style)."""
        cfg = self.cfg
        axes = self.axes
        B = shape.global_batch
        batch_ax = self.ok(B, tuple(axes.data))
        if batch_ax is not None:
            seq_ax = axes.model
        else:
            seq_ax = tuple(axes.data) + (axes.model,)
        W = cfg.sliding_window or shape.seq_len
        W = min(W, shape.seq_len)
        di = cfg.ssm_expand * cfg.d_model
        nh = cfg.n_heads
        hdm = di // max(nh, 1)
        d = cfg.d_model

        def kind_specs(kind: str) -> dict:
            if kind == "attn":
                sp = {
                    "k": P(None, batch_ax, self.ok(W, seq_ax), None, None),
                    "v": P(None, batch_ax, self.ok(W, seq_ax), None, None),
                }
                if cfg.kv_dtype == "int8":
                    sp["k_scale"] = P(None, batch_ax, self.ok(W, seq_ax),
                                      None, None)
                    sp["v_scale"] = P(None, batch_ax, self.ok(W, seq_ax),
                                      None, None)
                if cfg.is_enc_dec:
                    sp["enc_k"] = P(None, batch_ax, None, None, None)
                    sp["enc_v"] = P(None, batch_ax, None, None, None)
                return sp
            if kind == "mamba":
                return {
                    "h": P(None, batch_ax, self.ok(di, seq_ax), None),
                    "conv": P(None, batch_ax, None, self.ok(di, seq_ax)),
                }
            if kind == "mlstm":
                if batch_ax is not None:
                    c_spec = P(None, batch_ax, None, None,
                               self.ok(hdm, axes.model))
                else:
                    c_spec = P(None, None, None,
                               self.ok(hdm, tuple(axes.data)),
                               self.ok(hdm, axes.model))
                return {
                    "C": c_spec,
                    "n": P(None, batch_ax, None, None),
                    "m": P(None, batch_ax, None),
                    "F": P(None, batch_ax, None),
                }
            if kind == "slstm":
                return {k: P(None, batch_ax, self.ok(d, axes.model))
                        for k in ("h", "c", "n", "m")}
            raise ValueError(kind)

        p = block_period(cfg)
        return {
            f"pos{j}": kind_specs(kind)
            for j, (kind, _moe) in enumerate(cfg.layer_pattern()[:p])
        }

    # -------------------------------------------------------------- inputs
    def batch_spec(self, global_batch: int):
        return self.ok(global_batch, tuple(self.axes.data))


# ------------------------------------------------------------- public api
def build(cfg: ModelConfig, mesh: Mesh, axes: MeshAxes, fsdp: bool) -> SpecBuilder:
    return SpecBuilder(cfg, mesh, axes, fsdp)


def auto_fsdp_serving(cfg: ModelConfig, mesh: Mesh, axes: MeshAxes) -> bool:
    """Serving: params stay TP-only (no per-token FSDP gathers) unless the
    bf16 weights alone exceed the HBM budget (qwen3-235B: 29 GB/chip TP-16
    -> must stay data-sharded; EXPERIMENTS.md §Perf llama-decode iteration)."""
    _d, m = mesh_sizes(mesh, axes)
    bytes_per = 2 if cfg.param_dtype == "bfloat16" else 4
    return cfg.param_count() * bytes_per / m > 12e9


def auto_fsdp(cfg: ModelConfig, mesh: Mesh, axes: MeshAxes) -> bool:
    """Enable FSDP when TP-sharded params + Adam moments exceed ~1 GB/device
    (moments assumed fp32: 2 + 8 bytes per param)."""
    _d, m = mesh_sizes(mesh, axes)
    bytes_per = (2 if cfg.param_dtype == "bfloat16" else 4) + 8
    return cfg.param_count() * bytes_per / m > 1e9


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
