"""Distributed PCA via the Gram matrix (§3: the paper's PCA variant).

cov = E[xx^T] - mu mu^T with X^T X accumulated shard-locally (the Pallas
``gram`` kernel provides the MXU-tiled accumulation — kernels/gram.py) and
psum-merged; the (F,F) eigendecomposition is replicated — exactly MLlib's
RowMatrix.computePrincipalComponents split of work.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.estimator import DistContext, tree_aggregate


def _gram_stats(X):
    from repro.kernels import ops as kops
    g = kops.gram(X)                       # X^T X, Pallas kernel or jnp ref
    return {"g": g, "s": X.sum(0),
            "n": jnp.asarray(X.shape[0], jnp.float32)}


@dataclass
class PCA:
    n_components: int = 16

    def fit(self, X, ctx: DistContext = DistContext(), key=None):
        st = tree_aggregate(_gram_stats, ctx, X)
        n = jnp.maximum(st["n"], 1.0)
        mu = st["s"] / n
        cov = st["g"] / n - jnp.outer(mu, mu)
        evals, evecs = jnp.linalg.eigh(cov)            # ascending
        idx = jnp.argsort(evals)[::-1][: self.n_components]
        return {"mean": mu, "components": evecs[:, idx],
                "explained": evals[idx]}

    def transform(self, params, X):
        return (X - params["mean"]) @ params["components"]

    def fit_transform(self, X, ctx: DistContext = DistContext(), key=None):
        p = self.fit(X, ctx)
        return p, self.transform(p, X)
