"""Random Forest (§2.4.1): Poisson bootstrap + column sampling.

All trees grow level-synchronously in ONE SPMD program (the tree index is a
batch dim of the histogram — trees.grow_forest).  Bootstrapping uses
Poisson(1) example weights, the standard distributed approximation (Spark
uses it too: no global resample shuffle needed — weights are local).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.estimator import DistContext
from repro.core.trees import binarize, fit_bins, grow_forest, predict_class_forest


@dataclass
class RandomForest:
    n_classes: int
    n_trees: int = 20
    depth: int = 5
    n_bins: int = 32
    feature_frac: float = 0.35     # ~ sqrt(75)/75 ... 1/3, MLlib 'onethird'

    def fit(self, X, y, ctx: DistContext = DistContext(), weights=None,
            key=jax.random.PRNGKey(0)):
        n, F = X.shape
        edges = fit_bins(X, self.n_bins)
        Xb = binarize(X, edges)
        kb, kf = jax.random.split(key)
        # Poisson(1) bootstrap weights per (tree, example)
        bw = jax.random.poisson(kb, 1.0, (self.n_trees, n)).astype(jnp.float32)
        if weights is not None:
            bw = bw * weights[None]
        fmask = (jax.random.uniform(kf, (self.n_trees, F))
                 < self.feature_frac).astype(jnp.float32)
        fmask = jnp.maximum(fmask, jax.nn.one_hot(  # >=1 feature per tree
            jax.random.randint(kf, (self.n_trees,), 0, F), F))
        oh = jax.nn.one_hot(y, self.n_classes, dtype=jnp.float32)
        stat = oh[None] * bw[:, :, None]                       # (Tr,n,K)

        def run(xb, st):
            psum = (lambda h: h) if ctx.mesh is None else \
                (lambda h: jax.lax.psum(h, ctx.axis))
            return grow_forest(xb, st, depth=self.depth, n_bins=self.n_bins,
                               psum=psum, feature_mask=fmask)

        if ctx.mesh is None:
            tree = jax.jit(run)(Xb, stat)
        else:
            sh = jax.shard_map(run, mesh=ctx.mesh,
                               in_specs=(P(ctx.axis, None),
                                         P(None, ctx.axis, None)),
                               out_specs=P(), check_vma=False)
            tree = jax.jit(sh)(Xb, stat)
        return {"tree": tree, "edges": edges}

    def predict(self, params, X):
        Xb = binarize(X, params["edges"])
        ens, _ = predict_class_forest(params["tree"], Xb)
        return ens
