"""Evaluation metrics per the paper §3 (eqs. 1-3).

The confusion matrix is built as a one-hot x one-hot matmul — the
scatter-free MXU formulation (DESIGN §2) — and aggregated across shards with
``tree_aggregate`` (it's a sufficient statistic too).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.estimator import DistContext, tree_aggregate


def confusion_matrix(y_true, y_pred, n_classes: int,
                     ctx: DistContext = DistContext(), weights=None):
    def stats(yt, yp, w):
        ot = jax.nn.one_hot(yt, n_classes, dtype=jnp.float32) * w[:, None]
        op = jax.nn.one_hot(yp, n_classes, dtype=jnp.float32)
        return ot.T @ op                           # (true, pred)

    if weights is None:
        weights = jnp.ones(y_true.shape[:1], jnp.float32)
    return tree_aggregate(stats, ctx, y_true, y_pred, weights)


def classification_report(cm) -> Dict[str, float]:
    """Accuracy (eq.1), macro precision (eq.2), macro recall (eq.3), F1."""
    cm = jnp.asarray(cm, jnp.float32)
    tp = jnp.diag(cm)
    support = cm.sum(axis=1)                       # true counts
    predicted = cm.sum(axis=0)
    total = cm.sum()
    acc = tp.sum() / jnp.maximum(total, 1)
    prec_c = tp / jnp.maximum(predicted, 1e-9)
    rec_c = tp / jnp.maximum(support, 1e-9)
    present = support > 0
    nc = jnp.maximum(present.sum(), 1)
    precision = jnp.where(present, prec_c, 0).sum() / nc
    recall = jnp.where(present, rec_c, 0).sum() / nc
    f1_c = 2 * prec_c * rec_c / jnp.maximum(prec_c + rec_c, 1e-9)
    f1 = jnp.where(present, f1_c, 0).sum() / nc
    return {
        "accuracy": float(acc), "precision": float(precision),
        "recall": float(recall), "f1": float(f1),
        "per_class_precision": [float(x) for x in prec_c],
        "per_class_recall": [float(x) for x in rec_c],
    }


def evaluate(y_true, y_pred, n_classes: int,
             ctx: DistContext = DistContext()) -> Dict[str, float]:
    return classification_report(confusion_matrix(y_true, y_pred, n_classes, ctx))
