"""Gaussian Naive Bayes via one psum'd pass of per-class moments (§2.4.5).

Sufficient statistics: per-class (count, sum, sum-of-squares) — one
``tree_aggregate``; the model (priors, means, variances) is replicated.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.estimator import DistContext, tree_aggregate


@dataclass
class NaiveBayes:
    n_classes: int
    var_smoothing: float = 1e-6

    def fit(self, X, y, ctx: DistContext = DistContext(), weights=None, key=None):
        K = self.n_classes

        def stats(X, y, w):
            oh = jax.nn.one_hot(y, K, dtype=jnp.float32) * w[:, None]  # (n,K)
            count = oh.sum(0)                                          # (K,)
            s1 = oh.T @ X                                              # (K,F)
            s2 = oh.T @ (X * X)
            return {"count": count, "s1": s1, "s2": s2}

        if weights is None:
            weights = jnp.ones(X.shape[:1], jnp.float32)
        st = tree_aggregate(stats, ctx, X, y, weights)
        cnt = jnp.maximum(st["count"], 1e-9)[:, None]
        mean = st["s1"] / cnt
        var = jnp.maximum(st["s2"] / cnt - mean ** 2, 0) + self.var_smoothing
        prior = st["count"] / jnp.maximum(st["count"].sum(), 1e-9)
        return {"mean": mean, "var": var,
                "log_prior": jnp.log(jnp.maximum(prior, 1e-12))}

    def predict(self, params, X):
        mean, var = params["mean"], params["var"]             # (K,F)
        ll = -0.5 * (jnp.log(2 * jnp.pi * var)[None]
                     + (X[:, None, :] - mean[None]) ** 2 / var[None]).sum(-1)
        return jnp.argmax(ll + params["log_prior"][None], axis=-1)
