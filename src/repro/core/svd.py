"""Distributed randomized SVD (§3: the paper's SVD variant).

Halko-style: sketch Y = X Omega, then q power iterations of
Z = X^T (X Q) — each product is a shard-local matmul + psum (the only
cross-shard traffic) — and a small replicated QR/SVD.  MLlib computes SVD
via ARPACK on the driver with distributed mat-vecs; the structure (small
replicated solve + distributed products) is identical.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.estimator import DistContext, tree_aggregate


@dataclass
class SVD:
    n_components: int = 16
    oversample: int = 8
    power_iters: int = 2

    def fit(self, X, ctx: DistContext = DistContext(),
            key=jax.random.PRNGKey(0)):
        F = X.shape[1]
        k = min(self.n_components + self.oversample, F)
        omega = jax.random.normal(key, (F, k), jnp.float32)

        def xtx_mul(q):
            # X^T (X q), distributed over examples
            def stats(Xs):
                return (Xs.T @ (Xs @ q)).astype(jnp.float32)
            return tree_aggregate(stats, ctx, X)

        q, _ = jnp.linalg.qr(xtx_mul(omega))
        for _ in range(self.power_iters):
            q, _ = jnp.linalg.qr(xtx_mul(q))
        # Rayleigh-Ritz on the small subspace
        b = xtx_mul(q)                                  # (F,k) = X^T X q
        m = q.T @ b                                     # (k,k) symmetric
        evals, evecs = jnp.linalg.eigh(m)
        idx = jnp.argsort(evals)[::-1][: self.n_components]
        V = q @ evecs[:, idx]                           # right singular vecs
        sing = jnp.sqrt(jnp.maximum(evals[idx], 0.0))
        return {"components": V, "singular_values": sing}

    def transform(self, params, X):
        return X @ params["components"]

    def fit_transform(self, X, ctx: DistContext = DistContext(),
                      key=jax.random.PRNGKey(0)):
        p = self.fit(X, ctx, key)
        return p, self.transform(p, X)
