"""Multinomial (softmax) logistic regression (§3 Table 3).

Full-batch gradient descent with Nesterov momentum and L2, mirroring MLlib's
batch optimizer regime.  Data-parallel: each iteration is one
``tree_aggregate`` of (gradient, loss) — Spark's treeAggregate per LBFGS/GD
iteration, here a psum per step.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.estimator import DistContext


@dataclass
class LogisticRegression:
    n_classes: int
    iters: int = 100
    lr: float = 0.5
    l2: float = 1e-4
    momentum: float = 0.9

    def fit(self, X, y, ctx: DistContext = DistContext(), weights=None, key=None):
        n, F = X.shape
        K = self.n_classes
        if weights is None:
            weights = jnp.ones((n,), jnp.float32)

        def loss_fn(params, X, y, w):
            logits = X @ params["W"] + params["b"]
            oh = jax.nn.one_hot(y, K, dtype=jnp.float32)
            nll = (jax.nn.logsumexp(logits, -1) - (logits * oh).sum(-1)) * w
            wsum = jnp.maximum(w.sum(), 1e-9)
            return nll.sum() / wsum + 0.5 * self.l2 * jnp.sum(params["W"] ** 2)

        def train(X, y, w):
            params = {"W": jnp.zeros((F, K), jnp.float32),
                      "b": jnp.zeros((K,), jnp.float32)}
            vel = jax.tree.map(jnp.zeros_like, params)

            def step(carry, _):
                params, vel = carry
                g = jax.grad(loss_fn)(params, X, y, w)
                vel = jax.tree.map(
                    lambda v, gi: self.momentum * v - self.lr * gi, vel, g)
                params = jax.tree.map(lambda p, v: p + v, params, vel)
                return (params, vel), None

            (params, _), _ = jax.lax.scan(step, (params, vel), None,
                                          length=self.iters)
            return params

        if ctx.mesh is not None:
            shard = NamedSharding(ctx.mesh, P(ctx.axis))
            shard2 = NamedSharding(ctx.mesh, P(ctx.axis, None))
            fit = jax.jit(train,
                          in_shardings=(shard2, shard, shard),
                          out_shardings=None)
            return fit(X, y, weights)
        return jax.jit(train)(X, y, weights)

    def predict(self, params, X):
        return jnp.argmax(X @ params["W"] + params["b"], axis=-1)
