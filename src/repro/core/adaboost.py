"""AdaBoost (SAMME) over histogram decision stumps (paper §2.4.3).

Multiclass SAMME: per round, fit a weighted shallow tree, compute weighted
error, re-weight examples.  Example weights live on their shards; the error
and the stump histograms are the only cross-shard traffic (psum) — the same
sufficient-statistics contract as everything else.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.estimator import DistContext
from repro.core.trees import binarize, fit_bins, grow_forest, forest_node_values


def _stump_predict(tree, xb):
    walk = forest_node_values(tree, xb)          # (1,n,L,K)
    cnt = walk.sum(-1)
    best = jnp.argmax(walk, axis=-1)
    pred = best[:, :, 0]
    for lvl in range(1, walk.shape[2]):
        pred = jnp.where(cnt[:, :, lvl] > 0, best[:, :, lvl], pred)
    return pred[0]                                # (n,)


@dataclass
class AdaBoost:
    n_classes: int
    n_rounds: int = 20
    depth: int = 2
    n_bins: int = 32

    def fit(self, X, y, ctx: DistContext = DistContext(), weights=None, key=None):
        n, F = X.shape
        K = self.n_classes
        edges = fit_bins(X, self.n_bins)
        Xb = binarize(X, edges)
        oh = jax.nn.one_hot(y, K, dtype=jnp.float32)

        def run(xb, y, oh):
            psum = (lambda v: v) if ctx.mesh is None else \
                (lambda v: jax.lax.psum(v, ctx.axis))
            w0 = jnp.ones(y.shape, jnp.float32)

            def round_fn(w, _):
                wsum = psum(w.sum())
                wn = w / jnp.maximum(wsum, 1e-12)
                stat = (oh * wn[:, None])[None]             # (1,n,K)
                tree = grow_forest(xb, stat, depth=self.depth,
                                   n_bins=self.n_bins, psum=psum)
                pred = _stump_predict(tree, xb)
                miss = (pred != y).astype(jnp.float32)
                err = jnp.clip(psum((wn * miss).sum()), 1e-9, 1 - 1e-9)
                alpha = jnp.log((1 - err) / err) + jnp.log(K - 1.0)
                w = wn * jnp.exp(alpha * miss)
                return w, (tree, alpha)

            _, (trees, alphas) = jax.lax.scan(round_fn, w0, None,
                                              length=self.n_rounds)
            return trees, alphas

        if ctx.mesh is None:
            trees, alphas = jax.jit(run)(Xb, y, oh)
        else:
            sh = jax.shard_map(
                run, mesh=ctx.mesh,
                in_specs=(P(ctx.axis, None), P(ctx.axis), P(ctx.axis, None)),
                out_specs=({"feat": P(), "thr": P(), "value": P()}, P()),
                check_vma=False)
            trees, alphas = jax.jit(sh)(Xb, y, oh)
        return {"trees": trees, "alphas": alphas, "edges": edges}

    def predict(self, params, X):
        Xb = binarize(X, params["edges"])
        R = params["alphas"].shape[0]
        votes = 0.0
        for r in range(R):
            tr = jax.tree.map(lambda a: a[r], params["trees"])
            pred = _stump_predict(tr, Xb)
            votes = votes + params["alphas"][r] * jax.nn.one_hot(
                pred, self.n_classes, dtype=jnp.float32)
        return jnp.argmax(votes, axis=-1)
