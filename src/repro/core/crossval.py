"""K-fold cross-validation + grid model selection for the classifier suite
(the paper's "future works" asks for elaborated diagnosis studies — this is
the substrate for them)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.estimator import DistContext


def kfold_indices(n: int, k: int, seed: int = 0):
    perm = np.random.default_rng(seed).permutation(n)
    folds = np.array_split(perm, k)
    for i in range(k):
        test = folds[i]
        train = np.concatenate([folds[j] for j in range(k) if j != i])
        yield train, test


def cross_validate(algo_factory, X, y, *, n_classes: int, k: int = 5,
                   ctx: DistContext = DistContext(), seed: int = 0
                   ) -> Dict[str, float]:
    """Returns mean/std accuracy over k folds."""
    accs = []
    X = np.asarray(X)
    y = np.asarray(y)
    for tr, te in kfold_indices(len(X), k, seed):
        algo = algo_factory()
        p = algo.fit(jnp.asarray(X[tr]), jnp.asarray(y[tr]), ctx,
                     key=jax.random.PRNGKey(seed))
        rep = metrics.evaluate(jnp.asarray(y[te]),
                               algo.predict(p, jnp.asarray(X[te])), n_classes)
        accs.append(rep["accuracy"])
    return {"acc_mean": float(np.mean(accs)), "acc_std": float(np.std(accs)),
            "folds": k}


def grid_search(algo_cls, grid: Dict[str, Sequence], X, y, *, n_classes: int,
                k: int = 3, ctx: DistContext = DistContext()) -> Dict:
    """Exhaustive grid over dataclass fields; returns the best setting."""
    keys = list(grid)
    best = None
    results = []
    import itertools
    for combo in itertools.product(*(grid[kk] for kk in keys)):
        kw = dict(zip(keys, combo))
        res = cross_validate(lambda: algo_cls(n_classes=n_classes, **kw),
                             X, y, n_classes=n_classes, k=k, ctx=ctx)
        results.append({**kw, **res})
        if best is None or res["acc_mean"] > best["acc_mean"]:
            best = {**kw, **res}
    return {"best": best, "all": results}
