"""Binned, level-wise histogram decision trees — MLlib's algorithm, MXU-shaped.

Spark MLlib grows trees level-by-level: each executor builds per-(node,
feature, bin) label histograms over its partition, the driver merges them and
picks splits.  We keep exactly that structure (it is the paper's §2.4.1/2.4.4
workhorse) but adapt it to TPU (DESIGN §2):

  * features are quantile-binned to uint8 (``fit_bins``/``binarize``);
  * per-level histograms are segment-sums over a fused (tree, node, bin)
    index — scatter of 4-byte stats, never of activations; the Pallas
    ``hist`` kernel provides the MXU one-hot-matmul formulation of the same
    contraction (kernels/hist.py) for the hot path;
  * histogram merging is a ``psum`` over the mesh ``data`` axis (Spark's
    treeAggregate);
  * a whole forest grows simultaneously — the tree index is just another
    batch dimension of the histogram.

Trees are complete binary trees of fixed ``depth`` (children of i are
2i+1/2i+2).  Split scoring: Gini gain (classification) or Newton gain
G_L^2/(H_L+lam) + G_R^2/(H_R+lam) (regression, used by GBT).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.estimator import DistContext


# ------------------------------------------------------------------ binning
def fit_bins(X, n_bins: int = 32):
    """Quantile bin edges (F, n_bins-1) — MLlib's findSplitsBins."""
    qs = jnp.linspace(0.0, 100.0, n_bins + 1)[1:-1]
    return jnp.percentile(X, qs, axis=0).T                 # (F, B-1)


def binarize(X, edges):
    """X (n,F) -> uint8 bins via branchless comparisons (vectorizes on VPU)."""
    return (X[:, :, None] >= edges[None]).sum(-1).astype(jnp.uint8)


# ------------------------------------------------------- histogram builder
def _level_hist(Xb, pos, stat, n_slots: int, n_bins: int, psum):
    """Histogram over (tree, node-slot, feature, bin, channel).

    Xb: (n,F) uint8; pos: (Tr,n) int32 node slots; stat: (Tr,n,C).
    Returns (Tr, n_slots, F, B, C), psum-merged across shards.
    """
    Tr, n, C = stat.shape
    F = Xb.shape[1]
    B = n_bins
    t_off = (jnp.arange(Tr, dtype=jnp.int32) * (n_slots * B))[:, None]
    base = t_off + pos * B                                  # (Tr,n)
    data = stat.reshape(Tr * n, C)

    def per_feature(xb_col):
        ids = (base + xb_col[None, :]).reshape(Tr * n)
        return jax.ops.segment_sum(data, ids, num_segments=Tr * n_slots * B)

    hists = jax.lax.map(per_feature, Xb.T.astype(jnp.int32))  # (F, Tr*S*B, C)
    hists = hists.reshape(F, Tr, n_slots, B, C).transpose(1, 2, 0, 3, 4)
    return psum(hists)


def _gini_scores(hist, count_eps=1e-9):
    """hist: (Tr,S,F,B,K) class counts -> split scores (Tr,S,F,B-1).

    Score = weighted impurity decrease of splitting node at bin <= b."""
    left = jnp.cumsum(hist, axis=3)[..., :-1, :]            # (Tr,S,F,B-1,K)
    total = hist.sum(3, keepdims=True)                      # (Tr,S,F,1,K)
    right = total - left
    nl = left.sum(-1)
    nr = right.sum(-1)
    nt = nl + nr

    def gini_counts(c, n):
        p = c / jnp.maximum(n[..., None], count_eps)
        return 1.0 - jnp.sum(p * p, axis=-1)

    g_t = gini_counts(jnp.broadcast_to(total, left.shape), nt)
    g_l = gini_counts(left, nl)
    g_r = gini_counts(right, nr)
    gain = nt * g_t - (nl * g_l + nr * g_r)
    return jnp.where(nt > 0, gain, -jnp.inf)


def _newton_scores(hist, lam: float = 1.0):
    """hist: (Tr,S,F,B,3) with channels (G,H,count) -> scores (Tr,S,F,B-1)."""
    left = jnp.cumsum(hist, axis=3)[..., :-1, :]
    total = hist.sum(3, keepdims=True)
    right = total - left
    gl, hl = left[..., 0], left[..., 1]
    gr, hr = right[..., 0], right[..., 1]
    score = gl * gl / (hl + lam) + gr * gr / (hr + lam)
    return jnp.where((left[..., 2] > 0) & (right[..., 2] > 0), score, -jnp.inf)


def grow_forest(Xb, stat, *, depth: int, n_bins: int, psum,
                feature_mask=None, mode: str = "gini", lam: float = 1.0):
    """Grow Tr complete trees of ``depth`` simultaneously.

    Xb: (n,F) uint8; stat: (Tr,n,C) per-sample channel stats
    (classification: one-hot(y) * weight; regression: (g*w, h*w, w)).
    feature_mask: optional (Tr,F) in {0,1} — random-forest column sampling.
    Returns {'feat': (Tr,T), 'thr': (Tr,T), 'value': (Tr,T,C)} with
    T = 2^(depth+1) - 1 complete-tree nodes.
    """
    Tr, n, C = stat.shape
    F = Xb.shape[1]
    pos = jnp.zeros((Tr, n), jnp.int32)
    feats, thrs, values = [], [], []
    score_fn = functools.partial(_newton_scores, lam=lam) \
        if mode == "newton" else _gini_scores

    for d in range(depth):
        S = 1 << d
        hist = _level_hist(Xb, pos, stat, S, n_bins, psum)  # (Tr,S,F,B,C)
        values.append(hist[:, :, 0].sum(2))                 # (Tr,S,C) node totals
        scores = score_fn(hist)                             # (Tr,S,F,B-1)
        if feature_mask is not None:
            scores = jnp.where(feature_mask[:, None, :, None] > 0,
                               scores, -jnp.inf)
        flat = scores.reshape(Tr, S, F * (n_bins - 1))
        best = jnp.argmax(flat, axis=-1)                    # (Tr,S)
        feat = (best // (n_bins - 1)).astype(jnp.int32)
        thr = (best % (n_bins - 1)).astype(jnp.int32)
        feats.append(feat)
        thrs.append(thr)
        # route samples: right if bin > thr
        f_i = jnp.take_along_axis(feat, pos, axis=1)        # (Tr,n)
        t_i = jnp.take_along_axis(thr, pos, axis=1)
        xb_if = Xb.astype(jnp.int32)[jnp.arange(n)[None, :], f_i]
        go = (xb_if > t_i).astype(jnp.int32)
        pos = 2 * pos + go                                  # slot within next level
    # leaf values
    S = 1 << depth
    hist = _level_hist(Xb, pos, stat, S, n_bins, psum)
    values.append(hist[:, :, 0].sum(2))

    feat_arr = jnp.concatenate(
        feats + [jnp.zeros((Tr, S), jnp.int32)], axis=1)    # leaves: dummy
    thr_arr = jnp.concatenate(
        thrs + [jnp.full((Tr, S), n_bins, jnp.int32)], axis=1)
    val_arr = jnp.concatenate(values, axis=1)               # (Tr,T,C)
    return {"feat": feat_arr, "thr": thr_arr, "value": val_arr}


def forest_node_values(tree, Xb):
    """Descend all trees; returns (value_walk (Tr,n,depth+1,C))."""
    Tr, T = tree["feat"].shape
    n = Xb.shape[0]
    D = (T + 1).bit_length() - 2        # T = 2^(D+1) - 1
    node = jnp.zeros((Tr, n), jnp.int32)
    vals = []
    Xi = Xb.astype(jnp.int32)
    for d in range(D + 1):
        vals.append(tree["value"][jnp.arange(Tr)[:, None], node])
        if d == D:
            break
        f_i = tree["feat"][jnp.arange(Tr)[:, None], node]
        t_i = tree["thr"][jnp.arange(Tr)[:, None], node]
        xb = Xi[jnp.arange(n)[None, :], f_i]
        node = 2 * node + 1 + (xb > t_i).astype(jnp.int32)
    return jnp.stack(vals, axis=2)                          # (Tr,n,D+1,C)


def predict_class_forest(tree, Xb):
    """Majority vote over trees; per tree, deepest node with support wins."""
    walk = forest_node_values(tree, Xb)                     # (Tr,n,L,C)
    cnt = walk.sum(-1)                                      # (Tr,n,L)
    best = jnp.argmax(walk, axis=-1)                        # (Tr,n,L)
    pred = best[:, :, 0]
    for lvl in range(1, walk.shape[2]):
        pred = jnp.where(cnt[:, :, lvl] > 0, best[:, :, lvl], pred)
    votes = jax.nn.one_hot(pred, walk.shape[-1], dtype=jnp.float32).sum(0)
    return jnp.argmax(votes, axis=-1), pred                 # ensemble, per-tree


def predict_value_forest(tree, Xb, lam: float = 1.0):
    """Regression leaf values -G/(H+lam), summed over trees (GBT uses lr)."""
    walk = forest_node_values(tree, Xb)                     # (Tr,n,L,3)
    leaf = walk[:, :, -1]
    val = -leaf[..., 0] / (leaf[..., 1] + lam)
    return val                                              # (Tr,n)


# ----------------------------------------------------------- public classes
@dataclass
class DecisionTree:
    n_classes: int
    depth: int = 5
    n_bins: int = 32

    def fit(self, X, y, ctx: DistContext = DistContext(), weights=None, key=None):
        edges = fit_bins(X, self.n_bins)
        Xb = binarize(X, edges)
        if weights is None:
            weights = jnp.ones(X.shape[:1], jnp.float32)
        stat = (jax.nn.one_hot(y, self.n_classes, dtype=jnp.float32)
                * weights[:, None])[None]                   # (1,n,K)

        if ctx.mesh is None:
            tree = jax.jit(lambda xb, st: grow_forest(
                xb, st, depth=self.depth, n_bins=self.n_bins,
                psum=lambda h: h))(Xb, stat)
        else:
            from jax.sharding import PartitionSpec as P

            def local(xb, st):
                return grow_forest(
                    xb, st, depth=self.depth, n_bins=self.n_bins,
                    psum=lambda h: jax.lax.psum(h, ctx.axis))

            sh = jax.shard_map(
                local, mesh=ctx.mesh,
                in_specs=(P(ctx.axis, None), P(None, ctx.axis, None)),
                out_specs=P(), check_vma=False)
            tree = jax.jit(sh)(Xb, stat)
        return {"tree": tree, "edges": edges}

    def predict(self, params, X):
        Xb = binarize(X, params["edges"])
        ens, _ = predict_class_forest(params["tree"], Xb)
        return ens
