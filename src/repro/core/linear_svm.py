"""One-vs-rest linear SVM with squared hinge loss (paper §2.4.6).

All K one-vs-rest problems train simultaneously (the weight matrix is
(F, K)); data-parallel full-batch subgradient descent, one psum per step —
same treeAggregate contract as MLlib's SVMWithSGD.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.estimator import DistContext


@dataclass
class LinearSVM:
    n_classes: int
    iters: int = 100
    lr: float = 0.1
    l2: float = 1e-3

    def fit(self, X, y, ctx: DistContext = DistContext(), weights=None, key=None):
        n, F = X.shape
        K = self.n_classes
        if weights is None:
            weights = jnp.ones((n,), jnp.float32)

        def loss_fn(params, X, y, w):
            margins = X @ params["W"] + params["b"]             # (n,K)
            t = 2.0 * jax.nn.one_hot(y, K, dtype=jnp.float32) - 1.0
            hinge = jnp.maximum(0.0, 1.0 - t * margins) ** 2
            wsum = jnp.maximum(w.sum(), 1e-9)
            return (hinge.sum(-1) * w).sum() / wsum \
                + 0.5 * self.l2 * jnp.sum(params["W"] ** 2)

        def train(X, y, w):
            params = {"W": jnp.zeros((F, K), jnp.float32),
                      "b": jnp.zeros((K,), jnp.float32)}

            def step(params, _):
                g = jax.grad(loss_fn)(params, X, y, w)
                return jax.tree.map(lambda p, gi: p - self.lr * gi, params, g), None

            params, _ = jax.lax.scan(step, params, None, length=self.iters)
            return params

        if ctx.mesh is not None:
            shard = NamedSharding(ctx.mesh, P(ctx.axis))
            shard2 = NamedSharding(ctx.mesh, P(ctx.axis, None))
            return jax.jit(train, in_shardings=(shard2, shard, shard))(X, y, weights)
        return jax.jit(train)(X, y, weights)

    def predict(self, params, X):
        return jnp.argmax(X @ params["W"] + params["b"], axis=-1)
