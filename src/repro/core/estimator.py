"""Distributed estimator substrate — Spark's treeAggregate as an ICI psum.

Every algorithm in the paper reduces to: partition the examples over
executors, compute local sufficient statistics, merge.  Spark merges via a
tree of JVM shuffles; on a TPU mesh the same contract is a ``shard_map`` over
the ``data`` axis with a ``lax.psum`` merge (DESIGN §1/§2).

``DistContext(mesh=None)`` runs the identical code path single-device — the
paper's "on the single machine" configuration.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class DistContext:
    """mesh=None: single machine.  Otherwise: data-parallel over ``axis``."""
    mesh: Optional[Mesh] = None
    axis: str = "data"

    @property
    def ways(self) -> int:
        return 1 if self.mesh is None else self.mesh.shape[self.axis]

    def shard_batch(self, *arrays):
        """Place arrays batch-sharded on the mesh (host -> device)."""
        if self.mesh is None:
            return arrays if len(arrays) > 1 else arrays[0]
        out = tuple(
            jax.device_put(a, NamedSharding(
                self.mesh, P(self.axis, *([None] * (a.ndim - 1)))))
            for a in arrays)
        return out if len(out) > 1 else out[0]


def tree_aggregate(stats_fn: Callable, ctx: DistContext, *arrays,
                   static_args: Tuple = ()) -> Any:
    """Compute ``sum over shards of stats_fn(local_arrays)`` — the Spark
    ``treeAggregate`` contract.  stats_fn returns a pytree of arrays that add.
    """
    f = functools.partial(stats_fn, *static_args)
    if ctx.mesh is None:
        return jax.jit(f)(*arrays)

    def local(*xs):
        return jax.tree.map(lambda s: jax.lax.psum(s, ctx.axis), f(*xs))

    nd = len(arrays)
    in_specs = tuple(P(ctx.axis, *([None] * (a.ndim - 1))) for a in arrays)
    out_spec = P()  # replicated after psum
    shmapped = jax.shard_map(
        local, mesh=ctx.mesh, in_specs=in_specs,
        out_specs=jax.tree.map(lambda _: out_spec, jax.eval_shape(f, *arrays)),
        check_vma=False)
    return jax.jit(shmapped)(*arrays)


def pad_examples(X, y, ways: int):
    """Pad example count to a multiple of the shard count (weight-0 rows)."""
    n = X.shape[0]
    rem = (-n) % ways
    if rem == 0:
        return X, y, jnp.ones((n,), jnp.float32)
    Xp = jnp.concatenate([X, jnp.zeros((rem,) + X.shape[1:], X.dtype)], 0)
    yp = jnp.concatenate([y, jnp.zeros((rem,), y.dtype)], 0)
    w = jnp.concatenate([jnp.ones((n,), jnp.float32),
                         jnp.zeros((rem,), jnp.float32)], 0)
    return Xp, yp, w
