"""Gradient-Boosted Trees (§2.4.2 "Gradient Random Forest" = MLlib GBT).

Two modes:

* ``multiclass`` (ours-fixed): softmax boosting — per round, K regression
  trees fit the per-class (gradient, hessian) with Newton leaf values
  (the K trees are one ``grow_forest`` call: tree dim = class dim).
* ``mllib2018`` (ours-faithful): Spark MLlib 2018 GBT was binary-only; the
  paper ran it on 6-class labels anyway and got accuracy 0.214 (~ one class's
  prevalence).  This mode reproduces the pathology: labels collapse to
  {class0 vs rest} and predictions only ever hit two of six classes.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.estimator import DistContext
from repro.core.trees import (binarize, fit_bins, grow_forest,
                              predict_value_forest)


@dataclass
class GradientBoostedTrees:
    n_classes: int
    n_rounds: int = 15
    depth: int = 4
    n_bins: int = 32
    lr: float = 0.3
    lam: float = 1.0
    mode: str = "multiclass"        # multiclass | mllib2018

    def _n_out(self):
        return 2 if self.mode == "mllib2018" else self.n_classes

    def fit(self, X, y, ctx: DistContext = DistContext(), weights=None, key=None):
        n, F = X.shape
        K = self._n_out()
        yk = jnp.minimum(y, 1) if self.mode == "mllib2018" else y
        edges = fit_bins(X, self.n_bins)
        Xb = binarize(X, edges)
        if weights is None:
            weights = jnp.ones((n,), jnp.float32)
        oh = jax.nn.one_hot(yk, K, dtype=jnp.float32)

        def run(xb, oh, w):
            psum = (lambda h: h) if ctx.mesh is None else \
                (lambda h: jax.lax.psum(h, ctx.axis))
            logits0 = jnp.zeros((xb.shape[0], K), jnp.float32)

            def round_fn(logits, _):
                p = jax.nn.softmax(logits, axis=-1)
                g = (p - oh) * w[:, None]                   # (n,K)
                h = (p * (1 - p)) * w[:, None]
                stat = jnp.stack(
                    [g.T, h.T, jnp.broadcast_to(w[None], (K, xb.shape[0]))],
                    axis=-1)                                # (K,n,3)
                tree = grow_forest(xb, stat, depth=self.depth,
                                   n_bins=self.n_bins, psum=psum,
                                   mode="newton", lam=self.lam)
                delta = predict_value_forest(tree, xb, lam=self.lam)  # (K,n)
                return logits + self.lr * delta.T, tree

            logits, trees = jax.lax.scan(round_fn, logits0, None,
                                         length=self.n_rounds)
            return trees

        if ctx.mesh is None:
            trees = jax.jit(run)(Xb, oh, weights)
        else:
            sh = jax.shard_map(run, mesh=ctx.mesh,
                               in_specs=(P(ctx.axis, None), P(ctx.axis, None),
                                         P(ctx.axis)),
                               out_specs={"feat": P(), "thr": P(),
                                          "value": P()},
                               check_vma=False)
            trees = jax.jit(sh)(Xb, oh, weights)
        return {"trees": trees, "edges": edges}

    def predict_logits(self, params, X):
        Xb = binarize(X, params["edges"])
        trees = params["trees"]
        R = trees["feat"].shape[0]
        logits = 0.0
        for r in range(R):
            tr = jax.tree.map(lambda a: a[r], trees)
            logits = logits + self.lr * predict_value_forest(
                tr, Xb, lam=self.lam).T
        return logits

    def predict(self, params, X):
        return jnp.argmax(self.predict_logits(params, X), axis=-1)
