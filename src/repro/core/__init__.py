# The paper's primary contribution: distributed sleep-stage classification —
# Spark-MLlib-style algorithms as data-parallel JAX (shard_map + psum).
from repro.core.estimator import DistContext, tree_aggregate
from repro.core import metrics
from repro.core.naive_bayes import NaiveBayes
from repro.core.logistic_regression import LogisticRegression
from repro.core.linear_svm import LinearSVM
from repro.core.trees import DecisionTree
from repro.core.forest import RandomForest
from repro.core.gbt import GradientBoostedTrees
from repro.core.adaboost import AdaBoost
from repro.core.pca import PCA
from repro.core.svd import SVD

ALGORITHMS = {
    "nb": NaiveBayes,
    "lr": LogisticRegression,
    "svm": LinearSVM,
    "dt": DecisionTree,
    "rf": RandomForest,
    "gbt": GradientBoostedTrees,
    "ada": AdaBoost,
}
TRANSFORMS = {"none": None, "pca": PCA, "svd": SVD}
