"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

MUST be the process entrypoint: the first two lines force 512 host devices
before jax initializes (dry-run only — tests/benches see 1 device).

Per combo we record:
  * compile success, bytes-per-device (memory_analysis)
  * HLO flops / bytes (cost_analysis)
  * collective bytes by op kind, parsed from the compiled HLO — ops inside
    while-loop bodies (the layer scan) are multiplied by the scan trip count
    (XLA's cost model counts loop bodies once; see EXPERIMENTS.md §Dry-run).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] --out dryrun.jsonl
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, get_config          # noqa: E402
from repro.configs.shapes import SHAPES                                  # noqa: E402
from repro.launch import inputs as inputs_lib                            # noqa: E402
from repro.launch.mesh import make_production_mesh                       # noqa: E402
from repro.models.transformer import block_period                        # noqa: E402
from repro.sharding import specs as specs_lib                            # noqa: E402
from repro.sharding.axes import axes_from_mesh                           # noqa: E402
from repro.train.loop import (TrainConfig, make_prefill, make_serve_step,  # noqa: E402
                              make_train_step)

from repro.launch.hloparse import collective_bytes, tpu_faithful_total    # noqa: E402
from repro.launch.flops import (roofline_terms, step_flops,               # noqa: E402
                                step_hbm_bytes)


def lower_combo(arch: str, shape_name: str, multi_pod: bool, fsdp=None):
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = axes_from_mesh(mesh)
    if fsdp is None:
        fsdp = (specs_lib.auto_fsdp(cfg, mesh, axes) if shape.kind == "train"
                else specs_lib.auto_fsdp_serving(cfg, mesh, axes))

    # dense/full-attention archs switch to sliding-window for long_500k
    if shape.name == "long_500k" and not cfg.sliding_window:
        has_recurrent = any(k in ("mamba", "mlstm", "slstm")
                            for k, _ in cfg.layer_pattern())
        if not has_recurrent or any(k == "attn" for k, _ in cfg.layer_pattern()):
            cfg = cfg.replace(sliding_window=8192)

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            tc = TrainConfig()
            step, sspecs, bspecs, _ctx = make_train_step(
                cfg, mesh, tc, shape, fsdp=fsdp)
            state = inputs_lib.state_struct(cfg, mesh, fsdp, tc)
            batch = inputs_lib.batch_struct(cfg, shape, mesh)
            lowered = step.lower(state, batch)
        elif shape.kind == "prefill":
            pf, *_ = make_prefill(cfg, mesh, shape, fsdp=fsdp)
            params = inputs_lib.params_struct(cfg, mesh, fsdp)
            batch = inputs_lib.batch_struct(cfg, shape, mesh)
            lowered = pf.lower(params, batch)
        else:
            st, *_ = make_serve_step(cfg, mesh, shape, fsdp=fsdp)
            params = inputs_lib.params_struct(cfg, mesh, fsdp)
            token, cache, pos = inputs_lib.decode_structs(cfg, shape, mesh)
            lowered = st.lower(params, token, cache, pos)
    return cfg, shape, mesh, lowered, fsdp


def run_combo(arch: str, shape_name: str, multi_pod: bool, verbose=True):
    t0 = time.time()
    cfg, shape, mesh, lowered, fsdp = lower_combo(arch, shape_name, multi_pod)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    nper = cfg.n_layers // block_period(cfg)
    hlo = compiled.as_text()
    coll, counts = collective_bytes(hlo)
    ndev = mesh.devices.size
    axes = axes_from_mesh(mesh)
    fl = step_flops(cfg, SHAPES_BY_NAME[shape_name])
    hb = step_hbm_bytes(cfg, SHAPES_BY_NAME[shape_name], mesh, axes, fsdp)
    coll_dev = tpu_faithful_total(coll)  # per-device (SPMD module), bf16-corrected
    rt = roofline_terms(fl["total"], hb["total"], coll_dev, ndev)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "fsdp": bool(fsdp),
        "kind": shape.kind,
        "ok": True,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "n_devices": ndev,
        "scan_trips": nper,
        "argument_bytes_per_dev": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes_per_dev": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes_per_dev": getattr(mem, "temp_size_in_bytes", 0),
        "hlo_flops_raw": ca.get("flops", 0.0),
        "hlo_bytes_raw": ca.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "collective_counts": counts,
        "analytic_flops_global": fl["total"],
        "model_flops": fl["model_flops"],
        "analytic_hbm_bytes_dev": hb["total"],
        "hbm_breakdown": {k: v for k, v in hb.items() if k != "total"},
        "collective_bytes_dev": coll_dev,
        "roofline": rt,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if verbose:
        print(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                combos.append((a, s.name))
    else:
        combos.append((args.arch, args.shape))

    recs = []
    for a, s in combos:
        try:
            recs.append(run_combo(a, s, args.multi_pod, verbose=not args.out))
            status = "OK"
        except Exception as e:  # noqa: BLE001 — record failures, keep going
            recs.append({"arch": a, "shape": s,
                         "mesh": "2x16x16" if args.multi_pod else "16x16",
                         "ok": False, "error": repr(e)[:500]})
            status = f"FAIL {type(e).__name__}"
        print(f"[dryrun] {a} x {s} ({'2x16x16' if args.multi_pod else '16x16'}): {status}",
              file=sys.stderr, flush=True)
    if args.out:
        with open(args.out, "a") as f:
            for r in recs:
                f.write(json.dumps(r) + "\n")
    if not all(r["ok"] for r in recs):
        sys.exit(1)


if __name__ == "__main__":
    main()
