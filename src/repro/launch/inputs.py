"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape, mesh)`` returns the exact kwargs the corresponding
step function is lowered with.  Frontends (VLM patches, audio frames) are
stubbed as precomputed embeddings per the carve-out (DESIGN §5).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models.kvcache import cache_layout
from repro.sharding import specs as specs_lib
from repro.sharding.axes import axes_from_mesh


def _sds(shape, dtype, mesh, spec):
    sharding = NamedSharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def token_counts(cfg: ModelConfig, shape: InputShape):
    """(text_tokens, frontend_len) for a train/prefill sequence."""
    if cfg.n_patches:
        return shape.seq_len - cfg.n_patches, cfg.n_patches
    if cfg.is_enc_dec:
        return shape.seq_len, cfg.n_frames
    return shape.seq_len, 0


def batch_struct(cfg: ModelConfig, shape: InputShape, mesh: Optional[Mesh]
                 ) -> Dict[str, Any]:
    """Train/prefill batch: tokens, labels (train only adds labels), frontend."""
    axes = axes_from_mesh(mesh) if mesh is not None else None
    if mesh is not None:
        sb = specs_lib.build(cfg, mesh, axes, fsdp=False)
        bax = sb.batch_spec(shape.global_batch)
    else:
        bax = None
    B = shape.global_batch
    S_text, F = token_counts(cfg, shape)
    out = {"tokens": _sds((B, S_text), jnp.int32, mesh, P(bax, None))}
    if shape.kind == "train":
        out["labels"] = _sds((B, S_text), jnp.int32, mesh, P(bax, None))
    if F and cfg.n_patches:
        out["frontend"] = _sds((B, F, cfg.d_model), jnp.float32, mesh,
                               P(bax, None, None))
    elif F:
        out["frontend"] = _sds((B, F, cfg.d_model), jnp.float32, mesh,
                               P(bax, None, None))
    return out


def decode_structs(cfg: ModelConfig, shape: InputShape, mesh: Optional[Mesh]):
    """(token, cache, pos) structs for serve_step."""
    B = shape.global_batch
    axes = axes_from_mesh(mesh) if mesh is not None else None
    if mesh is not None:
        sb = specs_lib.build(cfg, mesh, axes, fsdp=False)
        bax = sb.batch_spec(B)
        cspecs = specs_lib.build(cfg, mesh, axes, fsdp=False).cache_specs(shape)
    else:
        bax, cspecs = None, None
    token = _sds((B, 1), jnp.int32, mesh, P(bax, None))
    lay = cache_layout(cfg, B, shape.seq_len)
    cache = {}
    for pj, sub in lay.items():
        cache[pj] = {}
        for k, (s, dt) in sub.items():
            spec = cspecs[pj][k] if cspecs is not None else P()
            cache[pj][k] = _sds(s, dt, mesh, spec)
    pos = _sds((), jnp.int32, mesh, P())
    return token, cache, pos


def params_struct(cfg: ModelConfig, mesh: Optional[Mesh], fsdp: bool):
    """ShapeDtypeStructs for params via eval_shape (no allocation)."""
    from repro.models import transformer as tf
    shapes = jax.eval_shape(
        lambda k: tf.init_params(k, cfg), jax.random.PRNGKey(0))
    if mesh is None:
        return shapes
    axes = axes_from_mesh(mesh)
    specs = specs_lib.build(cfg, mesh, axes, fsdp).param_specs()
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def state_struct(cfg: ModelConfig, mesh, fsdp: bool, tc):
    from repro.train.loop import init_state, state_specs
    shapes = jax.eval_shape(
        lambda k: init_state(k, cfg, tc), jax.random.PRNGKey(0))
    if mesh is None:
        return shapes
    axes = axes_from_mesh(mesh)
    specs = state_specs(cfg, mesh, axes, fsdp,
                        zero1=getattr(tc, "zero1", False))
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
