"""Analytic FLOP / HBM-byte models per (arch x shape).

XLA's cost_analysis counts while bodies once (empirically verified), so the
compiled numbers undercount scanned layers by ~n_layers.  The roofline table
therefore uses this analytic model as the primary source, with the raw HLO
numbers reported as a cross-check (and validated against *unrolled* lowerings
for the hillclimb combos — EXPERIMENTS.md §Roofline).

Conventions:
  * matmul (m,k)x(k,n): 2mkn FLOPs.
  * train step = fwd + backward (2x) + remat re-forward (1x) on scanned
    layers = 4x layer fwd; embedding/logits 3x (not rematted).
  * MODEL_FLOPS = 6 * N * D (dense) or 6 * N_active * D (MoE) — the
    conventional "useful" count (no attention/remat), for the usefulness
    ratio.
  * bytes: per-device HBM traffic estimate — params (x reads per step),
    optimizer moments r/w, activation carries r/w, KV cache r/w.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models.attention import padded_heads
from repro.models.moe import padded_experts

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


def _block_fwd_flops_per_token(cfg: ModelConfig, kind: str, moe: bool,
                               ctx_len: float) -> float:
    """Forward FLOPs per token for one layer; ctx_len = avg attended length."""
    d = cfg.d_model
    hd = cfg.hd
    nhp, _ = padded_heads(cfg)
    nkv = cfg.n_kv_heads
    f = 0.0
    if kind == "attn":
        f += 2 * d * hd * (2 * nhp + 2 * nkv)          # q,k,v,o projections
        f += 2 * 2 * nhp * hd * ctx_len                # scores + AV
        if cfg.is_enc_dec:
            f += 2 * d * hd * (2 * nhp + 2 * nkv)      # cross-attn proj
            f += 2 * 2 * nhp * hd * cfg.n_frames
    elif kind == "mamba":
        di = cfg.ssm_expand * d
        N = cfg.ssm_d_state
        R = max(1, di // 16)
        f += 2 * d * 2 * di + 2 * cfg.ssm_d_conv * di
        f += 2 * di * (R + 2 * N) + 2 * R * di
        f += 10 * di * N                               # scan update+readout
        f += 2 * di * d
    elif kind == "mlstm":
        di = cfg.ssm_expand * d
        nh = cfg.n_heads
        hdm = di // nh
        f += 2 * d * 2 * di                            # up x/z
        f += 3 * 2 * di * hdm                          # blockdiag qkv
        f += 3 * 2 * nh * hdm * hdm                    # C update + readout
        f += 2 * di * d                                # down
    elif kind == "slstm":
        nh = max(cfg.n_heads, 1)
        f += 2 * d * 4 * d + 2 * 4 * d * (d // nh)
    # FFN
    if moe:
        fe = cfg.expert_ff
        mult = 3 if cfg.activation == "swiglu" else 2
        f += (cfg.top_k + cfg.n_shared_experts) * mult * 2 * d * fe
        f += 2 * d * padded_experts(cfg.n_experts)     # router
    elif cfg.d_ff:
        mult = 3 if cfg.activation == "swiglu" else 2
        f += mult * 2 * d * cfg.d_ff
    return f


def step_flops(cfg: ModelConfig, shape: InputShape) -> Dict[str, float]:
    """Global FLOPs for one step of the given kind."""
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    if shape.kind == "decode":
        tokens = float(B)                # one new token per sequence
        W = cfg.sliding_window or S
        ctx = float(min(W, S))
    else:
        tokens = float(B) * S
        window = cfg.sliding_window
        ctx = (S + 1) / 2 if not window else min(window, (S + 1) / 2)

    layer_fwd = sum(
        _block_fwd_flops_per_token(cfg, kind, moe, ctx) * tokens
        for kind, moe in cfg.layer_pattern())
    logits = 2 * d * cfg.vocab_size * tokens
    enc = 0.0
    if cfg.is_enc_dec:
        enc_tokens = float(B) * cfg.n_frames
        per = (2 * d * cfg.hd * (2 * padded_heads(cfg)[0] + 2 * cfg.n_kv_heads)
               + 2 * 2 * padded_heads(cfg)[0] * cfg.hd * cfg.n_frames
               + (3 if cfg.activation == "swiglu" else 2) * 2 * d * cfg.d_ff)
        enc = per * enc_tokens * cfg.n_enc_layers
        if shape.kind == "decode":
            enc = 0.0                    # encoder ran at prefill

    if shape.kind == "train":
        total = 4 * (layer_fwd + enc) + 3 * logits
    else:
        total = layer_fwd + enc + logits
        if shape.kind == "prefill":
            total = layer_fwd + enc + 2 * d * cfg.vocab_size * B  # last-tok logits

    model_flops = 6.0 * cfg.active_param_count() * tokens
    if shape.kind != "train":
        model_flops = 2.0 * cfg.active_param_count() * tokens
    return {"total": total, "layer_fwd": layer_fwd, "logits": logits,
            "enc": enc, "model_flops": model_flops, "tokens": tokens}


def per_device_state_bytes(cfg: ModelConfig, mesh, axes, fsdp: bool,
                           train: bool, moment_bytes: int = 8) -> float:
    """Exact per-device bytes of params (+ optimizer if train) from specs."""
    import jax
    import numpy as np
    from repro.launch import inputs as inputs_lib
    from repro.sharding import specs as specs_lib

    struct = inputs_lib.params_struct(cfg, None, fsdp)
    specs = specs_lib.build(cfg, mesh, axes, fsdp).param_specs()

    def ways(spec, shape):
        w = 1
        for ax, dim in zip(tuple(spec) + (None,) * (len(shape) - len(tuple(spec))),
                           shape):
            if ax is None:
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            for a in axs:
                w *= mesh.shape[a]
        return w

    total = 0.0
    leaves = jax.tree.leaves(struct, is_leaf=lambda x: hasattr(x, "shape"))
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: hasattr(x, "index") and not isinstance(x, dict))
    # walk jointly via flatten with paths to stay aligned
    sl = jax.tree_util.tree_flatten_with_path(struct)[0]
    pl = dict(jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: type(x).__name__ == "PartitionSpec")[0])
    for path, leaf in sl:
        spec = pl[path]
        n = float(np.prod(leaf.shape)) / ways(spec, leaf.shape)
        pb = leaf.dtype.itemsize
        total += n * (pb + (moment_bytes if train else 0))
    return total


def step_hbm_bytes(cfg: ModelConfig, shape: InputShape, mesh, axes,
                   fsdp: bool) -> Dict[str, float]:
    """Per-device HBM traffic estimate for one step (documented formulas).

    train:   weights read fwd + remat re-read + bwd read + write, moments r/w,
             grads r/w (fp32), activation carries write + 2 reads.
    prefill: weights read, cache write, activation stream r/w.
    decode:  weights read, cache read + slot write.
    """
    import numpy as np
    from repro.models.kvcache import cache_layout
    from repro.models.transformer import block_period
    from repro.sharding import specs as specs_lib

    d_ways, m_ways = 1, mesh.shape[axes.model]
    for a in axes.data:
        d_ways *= mesh.shape[a]
    n_dev = d_ways * m_ways

    pdev = per_device_state_bytes(cfg, mesh, axes, fsdp, train=False,
                                  moment_bytes=0)
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    nper = cfg.n_layers // block_period(cfg)
    bl = max(B // d_ways, 1)

    if shape.kind == "train":
        act_carries = cfg.n_layers * bl * S * d * 2.0       # bf16 layer inputs
        moments = per_device_state_bytes(cfg, mesh, axes, fsdp, train=True,
                                         moment_bytes=8) - pdev
        grads = pdev * 2                                     # fp32 vs bf16
        total = pdev * 4 + moments * 2 + grads * 2 + act_carries * 3
        return {"total": total, "params": pdev, "moments": moments,
                "act_carries": act_carries}

    # serving: cache bytes per device from the cache specs
    sb = specs_lib.build(cfg, mesh, axes, fsdp)
    cspecs = sb.cache_specs(shape)
    lay = cache_layout(cfg, B, S)
    cache_dev = 0.0
    for pj, sub in lay.items():
        for k, (shp, dt) in sub.items():
            spec = cspecs[pj][k]
            w = 1
            for ax, dim in zip(tuple(spec) + (None,) * (len(shp) - len(tuple(spec))), shp):
                if ax is None:
                    continue
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    w *= mesh.shape[a]
            cache_dev += float(np.prod(shp)) * np.dtype(dt).itemsize / w
    if shape.kind == "decode":
        total = pdev + cache_dev            # read weights + read cache (+eps)
    else:
        acts = bl * S * d * 2.0 * cfg.n_layers * 2
        total = pdev + cache_dev + acts
    return {"total": total, "params": pdev, "cache": cache_dev}


def roofline_terms(flops_global: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, n_devices: int,
                   ici_links: int = 4) -> Dict[str, float]:
    t_compute = flops_global / n_devices / PEAK_FLOPS
    t_memory = bytes_per_dev / HBM_BW
    t_coll = coll_bytes_per_dev / (ici_links * ICI_BW)
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])
    return {"compute_s": t_compute, "memory_s": t_memory,
            "collective_s": t_coll, "dominant": dom[0]}
