"""Batched serving driver: prefill a batch of prompts, then decode tokens.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch llama3.2-3b --smoke --batch 4 --prompt-len 64 --gen 32

Implements the production serving split: one prefill program (chunked
attention over the prompt, emits the KV cache) + one decode program (single
token against the circular cache), both jitted once and reused.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.configs.shapes import InputShape
from repro.data.pipeline import token_stream
from repro.models import transformer as tf
from repro.sharding.axes import make_test_mesh
from repro.train.loop import make_prefill, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_test_mesh()
    # decode cache covers prompt + generation
    total = args.prompt_len + args.gen
    shape_pf = InputShape("pf", args.prompt_len, args.batch, "prefill")
    shape_dec = InputShape("dec", total, args.batch, "decode")

    key = jax.random.PRNGKey(args.seed)
    with jax.set_mesh(mesh):
        params = tf.init_params(key, cfg)
        pf, *_ = make_prefill(cfg, mesh, shape_pf,
                              q_chunk=min(512, args.prompt_len), fsdp=False)
        dec, *_ = make_serve_step(cfg, mesh, shape_dec, fsdp=False,
                                  donate=False)

        batch = next(token_stream(cfg, args.batch, args.prompt_len, args.seed))
        batch.pop("labels", None)
        t0 = time.time()
        logits, cache = pf(params, batch)
        print(f"prefill: {args.batch}x{args.prompt_len} in {time.time()-t0:.2f}s")

        # prefill cache is sized prompt_len; decode cache is sized total —
        # re-seat the prefill entries into the larger circular buffer
        from repro.models.kvcache import grow_cache
        cache = grow_cache(cache, cfg, args.batch, total)

        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens = [tok]
        t0 = time.time()
        for i in range(args.gen):
            pos = jnp.int32(args.prompt_len + i)
            logits, cache = dec(params, tok, cache, pos)
            if args.temperature > 0:
                key, sk = jax.random.split(key)
                tok = jax.random.categorical(
                    sk, logits[:, 0] / args.temperature)[:, None].astype(jnp.int32)
            else:
                tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
            out_tokens.append(tok)
        dt = time.time() - t0
        gen = jnp.concatenate(out_tokens, axis=1)
        print(f"decode: {args.gen} steps x batch {args.batch} in {dt:.2f}s "
              f"({args.gen * args.batch / dt:.1f} tok/s)")
        print("sampled token ids (seq 0):", gen[0].tolist())
    print("done")


if __name__ == "__main__":
    main()
