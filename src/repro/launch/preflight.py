"""Preflight checker: validate (arch x shape x mesh) before committing to a
compile — divisibility, memory napkin math, and sharding coverage.

    PYTHONPATH=src python -m repro.launch.preflight [--arch a] [--multi-pod]

Prints one line per check; exits non-zero on hard failures.  The dry-run
proves compile-correctness; preflight explains *why* a config is laid out
the way it is (which dims shard, what falls back to replication, projected
per-chip state bytes) without any XLA work — the first thing an oncall
would run.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Tuple

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.models.attention import padded_heads
from repro.models.moe import padded_experts
from repro.models.transformer import block_period


def check_arch(cfg, data_ways: int, model_ways: int) -> Tuple[List[str], List[str]]:
    ok, warn = [], []
    nhp, G = padded_heads(cfg)
    if nhp != cfg.n_heads:
        warn.append(f"q-heads padded {cfg.n_heads}->{nhp} for TP{model_ways} "
                    f"(+{100*(nhp-cfg.n_heads)/cfg.n_heads:.0f}% attn FLOPs)")
    ok.append(f"attn heads: {nhp} = {cfg.n_kv_heads}kv x {G}G "
              f"({'kv' if cfg.n_kv_heads % model_ways == 0 else 'flat-head'}-sharded)")
    if cfg.n_kv_heads % model_ways:
        warn.append(f"kv projections replicate over model axis "
                    f"({cfg.n_kv_heads} kv heads !% {model_ways})")
    if cfg.d_ff and cfg.d_ff % model_ways:
        warn.append(f"d_ff={cfg.d_ff} !% {model_ways}: MLP replicates (BAD)")
    else:
        ok.append(f"d_ff {cfg.d_ff or '—'} TP-sharded")
    if cfg.n_experts:
        ep = padded_experts(cfg.n_experts)
        if ep != cfg.n_experts:
            warn.append(f"experts padded {cfg.n_experts}->{ep} "
                        f"({ep - cfg.n_experts} dead experts)")
        ok.append(f"experts: {ep} over model axis = {ep // model_ways}/chip")
    p = block_period(cfg)
    ok.append(f"scan: period {p} x {cfg.n_layers // p} trips")
    # memory napkin (training, fp32 moments)
    n = cfg.param_count()
    state = n * 10 / (data_ways * model_ways)
    if state > 12e9:
        warn.append(f"train state {state/1e9:.1f}GB/chip with fp32 moments "
                    f"(> ~12GB budget) — use bf16 moments "
                    f"({n*6/(data_ways*model_ways)/1e9:.1f}GB)")
    else:
        ok.append(f"train state {state/1e9:.2f}GB/chip (fp32 moments)")
    return ok, warn


def check_shape(cfg, shape, data_ways: int, model_ways: int):
    ok, warn, fail = [], [], []
    if shape.kind == "train" and shape.global_batch % data_ways:
        fail.append(f"batch {shape.global_batch} !% data {data_ways}")
    if shape.kind == "decode":
        W = cfg.sliding_window or shape.seq_len
        if shape.global_batch == 1:
            ways = data_ways * model_ways
            if W % ways:
                warn.append(f"cache seq {W} !% {ways}: partial seq-sharding")
            else:
                ok.append(f"cache seq-sharded {ways}-way ({W//ways}/chip)")
        has_rec = any(k != "attn" for k, _ in cfg.layer_pattern())
        if shape.seq_len >= 500_000 and not (has_rec or cfg.sliding_window):
            warn.append("long_500k on full attention: runs via the "
                        "sliding-window variant (window 8192)")
    return ok, warn, fail


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    data_ways = 32 if args.multi_pod else 16
    model_ways = 16
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    failures = 0
    for a in archs:
        cfg = get_config(a)
        print(f"\n== {a} ({cfg.arch_type}, {cfg.param_count()/1e9:.2f}B) ==")
        ok, warn = check_arch(cfg, data_ways, model_ways)
        for m in ok:
            print(f"  [ok]   {m}")
        for m in warn:
            print(f"  [warn] {m}")
        for shape in SHAPES:
            so, sw, sf = check_shape(cfg, shape, data_ways, model_ways)
            for m in so:
                print(f"  [ok]   {shape.name}: {m}")
            for m in sw:
                print(f"  [warn] {shape.name}: {m}")
            for m in sf:
                print(f"  [FAIL] {shape.name}: {m}")
                failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
