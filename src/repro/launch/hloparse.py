"""Post-optimization HLO analysis: collective bytes with loop multipliers.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE (verified
empirically); the same holds for any byte counting over the HLO text.  This
parser recovers true totals:

1. split the module into computations,
2. find every ``while`` op, its body computation, and its
   ``backend_config={"known_trip_count":{"n":K}}``,
3. propagate multipliers through (possibly nested) loops,
4. sum collective output bytes x multiplier per collective kind.

Output bytes are used as the traffic proxy per op (all-reduce: |msg|,
all-gather: gathered size, reduce-scatter: pre-reduce input ~ output x ways;
a uniform, documented convention — see EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
                "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*\{")
_WHILE_RE = re.compile(r"while\(.*?body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(stext: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(stext):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    entry = None
    for line in hlo.splitlines():
        # computation headers sit at column 0: "[ENTRY ]%name (params) -> ty {"
        if (line and not line[0].isspace() and "->" in line
                and line.rstrip().endswith("{")):
            name = line.strip()
            is_entry = name.startswith("ENTRY")
            if is_entry:
                name = name[len("ENTRY"):].strip()
            name = name.lstrip("%").split("(")[0].strip().split()[0]
            cur = name
            comps[cur] = []
            if is_entry:
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    if entry is not None:
        comps["__entry__"] = comps.get(entry, [])
        comps.setdefault("__entry_name__", [entry])  # marker
    return comps


def loop_multipliers(hlo: str) -> Dict[str, int]:
    """computation name -> effective execution count (entry = 1)."""
    comps = _split_computations(hlo)
    entry_name = comps.get("__entry_name__", [None])[0]
    # call sites: (parent_comp, body_comp, trip)
    sites: List[Tuple[str, str, int]] = []
    for cname, lines in comps.items():
        if cname.startswith("__entry"):
            continue
        for ln in lines:
            if " while(" not in ln:
                continue
            wm = _WHILE_RE.search(ln)
            if not wm:
                continue
            tm = _TRIP_RE.search(ln)
            trip = int(tm.group(1)) if tm else 1
            sites.append((cname, wm.group(1), trip))
    mult: Dict[str, int] = defaultdict(int)
    if entry_name:
        mult[entry_name] = 1
    # fixpoint over the (acyclic) call graph
    for _ in range(64):
        changed = False
        new = defaultdict(int)
        if entry_name:
            new[entry_name] = 1
        for parent, body, trip in sites:
            if mult.get(parent, 0):
                new[body] += mult[parent] * trip
        for k, v in new.items():
            if mult.get(k, 0) != v:
                changed = True
        if not changed:
            break
        mult = new
    return dict(mult)


_OPERAND_RE = re.compile(r"\((%[\w.\-]+)")


def collective_bytes(hlo: str) -> Tuple[Dict[str, float], Dict[str, int]]:
    """(bytes by kind, op-executions by kind), loop-aware.

    Also emits ``<kind>_tpu`` entries for the reducing collectives: the CPU
    backend's FloatNormalization pass promotes bf16 all-reduce /
    reduce-scatter to fp32 (verified with a minimal repro: a pure bf16 psum
    lowers to ``all-reduce(f32(convert(...)))`` on CPU).  Ops whose operand
    is a convert fusion are counted at half size in the ``_tpu`` entry —
    the TPU-faithful byte count the roofline uses (EXPERIMENTS.md §Roofline).
    """
    comps = _split_computations(hlo)
    mult = loop_multipliers(hlo)
    out = {k: 0.0 for k in COLLECTIVES}
    out.update({f"{k}_tpu": 0.0 for k in ("all-reduce", "reduce-scatter")})
    counts = {k: 0 for k in COLLECTIVES}
    for cname, lines in comps.items():
        if cname.startswith("__entry"):
            continue
        # collectives live in the entry or in while bodies; computations we
        # couldn't attribute (fusions/conds — which hold no collectives)
        # default to counting once.
        m = mult.get(cname, 1)
        for ln in lines:
            for kind in COLLECTIVES:
                # match "= <shape> all-reduce(" and "-start(" variants
                if f" {kind}(" in ln or f" {kind}-start(" in ln:
                    lhs = ln.split("=", 1)[1].strip() if "=" in ln else ln
                    b = shape_bytes(lhs.split(f" {kind}")[0])
                    out[kind] += float(b) * m
                    counts[kind] += m
                    if kind in ("all-reduce", "reduce-scatter"):
                        om = _OPERAND_RE.search(ln.split(kind, 1)[1])
                        promoted = bool(om and "convert" in om.group(1))
                        out[f"{kind}_tpu"] += float(b) * m * (0.5 if promoted else 1.0)
    return out, counts


def tpu_faithful_total(coll: Dict[str, float]) -> float:
    """Per-device collective bytes with the CPU bf16-promotion undone."""
    total = 0.0
    for k in COLLECTIVES:
        total += coll.get(f"{k}_tpu", coll.get(k, 0.0))
    return total
