"""End-to-end LM training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch llama3.2-3b --smoke --steps 50 --batch 8 --seq 256

``--smoke`` uses the reduced config (CPU-trainable ~100M-scale runs use
``--smoke --d-model 512 ...`` overrides); full configs are for real
hardware.  Checkpoints every ``--ckpt-every`` steps; resumes from the
latest checkpoint in ``--ckpt-dir``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES_BY_NAME, get_config, get_smoke_config
from repro.configs.shapes import InputShape
from repro.data.pipeline import token_stream
from repro.sharding.axes import make_test_mesh
from repro.train import checkpoint as ckpt_lib
from repro.train.loop import TrainConfig, init_state, make_train_step
from repro.train.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    over = {}
    if args.d_model:
        over.update(d_model=args.d_model)
    if args.n_layers:
        over.update(n_layers=args.n_layers)
    if over:
        cfg = cfg.replace(**over)

    shape = InputShape("cli", args.seq, args.batch, "train")
    mesh = make_test_mesh(args.mesh_data, args.mesh_model)
    tc = TrainConfig(opt=OptConfig(lr=args.lr, total_steps=args.steps,
                                   warmup_steps=max(args.steps // 20, 5)),
                     q_chunk=min(1024, args.seq), microbatches=1)

    with jax.set_mesh(mesh):
        step_fn, sspecs, _b, _ctx = make_train_step(cfg, mesh, tc, shape,
                                                    fsdp=False, donate=True)
        start = 0
        if args.ckpt_dir and (s := ckpt_lib.latest_step(args.ckpt_dir)) is not None:
            struct = jax.eval_shape(
                lambda k: init_state(k, cfg, tc), jax.random.PRNGKey(args.seed))
            state = ckpt_lib.restore(
                os.path.join(args.ckpt_dir, f"step_{s}"), struct)
            start = s
            print(f"resumed from step {s}")
        else:
            state = init_state(jax.random.PRNGKey(args.seed), cfg, tc)
        n_params = sum(int(x.size) for x in jax.tree.leaves(state["params"]))
        print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
              f"mesh={dict(mesh.shape)} tokens/step={args.batch * args.seq}")

        stream = token_stream(cfg, args.batch, args.seq, args.seed, start)
        t0 = time.time()
        for i, batch in zip(range(start, args.steps), stream):
            state, metrics = step_fn(state, batch)
            if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
                m = {k: float(v) for k, v in metrics.items()}
                dt = time.time() - t0
                tps = args.log_every * args.batch * args.seq / max(dt, 1e-9)
                print(f"step {i+1:5d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                      f"gnorm={m['grad_norm']:.2f} lr={m['lr']:.2e} "
                      f"tok/s={tps:,.0f}")
                t0 = time.time()
            if args.ckpt_every and args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                ckpt_lib.save(os.path.join(args.ckpt_dir, f"step_{i+1}"),
                              state, step=i + 1)
        if args.ckpt_dir:
            ckpt_lib.save(os.path.join(args.ckpt_dir, f"step_{args.steps}"),
                          state, step=args.steps)
    print("done")


if __name__ == "__main__":
    main()
