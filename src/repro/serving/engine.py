"""Batched serving engine: request queue -> padded prefill batches ->
lockstep decode -> per-request completion.

Production shape without dynamic shapes: requests are bucketed by prompt
length (padded to the bucket), prefilled as one batch, then decoded in
lockstep against the shared circular KV cache.  Left-padding keeps every
request's last prompt token aligned at the same position, so the scalar
decode position is valid batch-wide; pad tokens are masked from attention
by their slot validity (they occupy slots before every real token's
window... they are attended but carry the pad embedding — acceptable for
synthetic serving; a per-slot position variant is the engine's TODO and is
measured in EXPERIMENTS.md §Perf as future work).

The engine is deliberately host-side simple: all device work goes through
the two jitted programs from ``train.loop`` (prefill, serve_step), which are
the same programs the multi-pod dry-run lowers.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models.kvcache import grow_cache
from repro.train.loop import make_prefill, make_serve_step


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (L,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    """Static-batching engine over the framework's prefill/decode programs."""

    def __init__(self, params, cfg: ModelConfig, mesh, *, batch: int = 4,
                 bucket: int = 64, max_total: int = 256, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.mesh = mesh
        self.batch = batch
        self.bucket = bucket
        self.max_total = max_total
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        shape_pf = InputShape("pf", bucket, batch, "prefill")
        shape_dec = InputShape("dec", max_total, batch, "decode")
        self._prefill, *_ = make_prefill(cfg, mesh, shape_pf,
                                         q_chunk=min(512, bucket), fsdp=False)
        self._decode, *_ = make_serve_step(cfg, mesh, shape_dec, fsdp=False,
                                           donate=False)

    # ------------------------------------------------------------- public
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0) -> int:
        rid = len(self.finished) + len(self.queue)
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens, temperature,
                                  t_enqueue=time.time()))
        return rid

    def run(self) -> Dict[int, Request]:
        """Drain the queue; returns finished requests."""
        while self.queue:
            batch_reqs = self.queue[: self.batch]
            self.queue = self.queue[self.batch:]
            self._serve_batch(batch_reqs)
        return self.finished

    def stats(self) -> Dict[str, float]:
        reqs = list(self.finished.values())
        if not reqs:
            return {}
        ttft = [r.t_first_token - r.t_enqueue for r in reqs]
        total = [r.t_done - r.t_enqueue for r in reqs]
        toks = sum(len(r.out_tokens) for r in reqs)
        span = max(r.t_done for r in reqs) - min(r.t_enqueue for r in reqs)
        return {"requests": len(reqs), "tokens": toks,
                "ttft_mean_s": float(np.mean(ttft)),
                "latency_mean_s": float(np.mean(total)),
                "throughput_tok_s": toks / max(span, 1e-9)}

    # ------------------------------------------------------------ private
    def _serve_batch(self, reqs: List[Request]) -> None:
        cfg = self.cfg
        B, L = self.batch, self.bucket
        toks = np.zeros((B, L), np.int32)
        for i, r in enumerate(reqs):
            p = r.prompt[-L:]
            toks[i, L - len(p):] = p                      # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.n_patches:
            batch["frontend"] = jnp.zeros((B, cfg.n_patches, cfg.d_model))
        elif cfg.is_enc_dec:
            batch["frontend"] = jnp.zeros((B, cfg.n_frames, cfg.d_model))
        logits, cache = self._prefill(self.params, batch)
        with jax.set_mesh(self.mesh):
            cache = grow_cache(cache, cfg, B, self.max_total)
        now = time.time()
        tok = np.asarray(jnp.argmax(logits[:, -1], axis=-1))[:, None]
        for i, r in enumerate(reqs):
            r.out_tokens.append(int(tok[i, 0]))
            r.t_first_token = now
        max_gen = max(r.max_new_tokens for r in reqs)
        pos0 = L + (cfg.n_patches or 0)
        cur = jnp.asarray(tok, jnp.int32)
        for step in range(1, max_gen):
            lg, cache = self._decode(self.params, cur, cache,
                                     jnp.int32(pos0 + step - 1))
            temp = max((r.temperature for r in reqs), default=0.0)
            if temp > 0:
                self.key, sk = jax.random.split(self.key)
                cur = jax.random.categorical(sk, lg[:, 0] / temp)[:, None]
            else:
                cur = jnp.argmax(lg[:, 0], axis=-1)[:, None]
            cur = cur.astype(jnp.int32)
            vals = np.asarray(cur)
            for i, r in enumerate(reqs):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(vals[i, 0]))
        now = time.time()
        for r in reqs:
            r.done = True
            r.t_done = now
            self.finished[r.rid] = r
