"""Sliding-window flash attention (pl.pallas_call + BlockSpec).

Online-softmax attention over a banded causal mask — the kernel that makes
``long_500k`` viable for the dense/MoE/VLM/audio architectures (DESIGN §5)
and the prefill hot path.  Grid: (batch*heads, q_blocks, k_blocks) with the
k dimension innermost (sequential on TPU): VMEM scratch carries the running
max / denominator / output accumulator across k blocks; out-of-band blocks
are skipped via @pl.when (they cost a predicate, not FLOPs).

Layout: q,k,v (B*H, S, D) — heads pre-flattened, kv pre-expanded to query
heads (GQA expansion happens in the wrapper; D and block sizes are
128-aligned for the MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
BLOCK_Q = 128
BLOCK_K = 128


def _kernel(window: int, causal: bool, scale: float,
            q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * BLOCK_Q
    k_lo = ki * BLOCK_K
    # block is live iff some (qpos >= kpos) and (kpos > qpos - window)
    live = True
    if causal:
        live = k_lo <= q_lo + BLOCK_Q - 1
    if window:
        live = jnp.logical_and(live, k_lo + BLOCK_K - 1 > q_lo - window)

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32)                   # (BQ, D)
        k = k_ref[0].astype(jnp.float32)                   # (BK, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = mask & (kpos <= qpos)
        if window:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                # (BQ, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def swa_attention_pallas(q, k, v, *, window: int = 0, causal: bool = True,
                         interpret: bool = True, scale: float = 0.0):
    """q,k,v: (BH, S, D); returns (BH, S, D).  S % 128 == 0, D % 128 == 0.
    ``scale``: softmax scale (pass the UNpadded D^-0.5 when D was padded)."""
    BH, S, D = q.shape
    assert S % BLOCK_Q == 0 and S % BLOCK_K == 0, S
    scale = scale or D ** -0.5
    grid = (BH, S // BLOCK_Q, S // BLOCK_K)
    return pl.pallas_call(
        functools.partial(_kernel, window, causal, scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_Q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BLOCK_K, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BLOCK_K, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_Q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),
            pltpu.VMEM((BLOCK_Q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
