"""Scatter-free histogram build: one-hot^T @ stats on the MXU
(pl.pallas_call + BlockSpec).

TPUs have no fast scatter-add; the decision-tree histogram
h[(node,bin), c] += stat[i, c] becomes a matmul between an on-the-fly
one-hot matrix (chunk x node*bin) and the stat chunk (chunk x C) — the
paper's MLlib tree aggregation re-thought for a systolic array (DESIGN §2).
Grid dim 1 accumulates over example chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 512


def _kernel(n_slots: int, ids_ref, stat_ref, o_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]                                     # (TN, 1) int32
    stat = stat_ref[...].astype(jnp.float32)               # (TN, C)
    slots = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], n_slots), 1)
    onehot = (slots == ids).astype(jnp.float32)            # (TN, S*B)
    o_ref[...] += jax.lax.dot_general(
        onehot, stat, (((0,), (0,)), ((), ())),            # onehot^T @ stat
        preferred_element_type=jnp.float32)


def hist_pallas(ids, stat, n_slots: int, interpret: bool = True):
    """ids (n,1) int32 in [0, n_slots); stat (n, C) -> (n_slots, C) fp32."""
    n, C = stat.shape
    assert n % TILE_N == 0, n
    return pl.pallas_call(
        functools.partial(_kernel, n_slots),
        grid=(n // TILE_N,),
        in_specs=[
            pl.BlockSpec((TILE_N, 1), lambda k: (k, 0)),
            pl.BlockSpec((TILE_N, C), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((n_slots, C), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_slots, C), jnp.float32),
        interpret=interpret,
    )(ids, stat)
