"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function is the semantic ground truth the kernels/tests assert against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-9


# ------------------------------------------------------------- band_stats
def band_stats_ref(xs):
    """xs: (..., T) SORTED ascending.  Returns (..., 15) statistics
    (see data/features.py for the catalogue)."""
    T = xs.shape[-1]
    mean = xs.mean(-1)
    hmean = 1.0 / jnp.maximum(jnp.mean(1.0 / (jnp.abs(xs) + 1e-3), -1), EPS)
    i25, i50, i75 = (25 * (T - 1)) // 100, (T - 1) // 2, (75 * (T - 1)) // 100
    q25 = xs[..., i25]
    med = xs[..., i50]
    q75 = xs[..., i75]
    iqr = q75 - q25
    # trimmed mean: mean over the central [q25, q75] positions (sorted input
    # makes this a static index range)
    inner = xs[..., i25:i75 + 1]
    tmean = inner.mean(-1)
    energy = jnp.sum(xs * xs, -1)
    p = (xs * xs) / jnp.maximum(energy[..., None], EPS)
    entropy = -jnp.sum(p * jnp.log(p + EPS), -1)
    mn = xs[..., 0]
    mx = xs[..., -1]
    var = jnp.maximum(jnp.mean(xs * xs, -1) - mean * mean, 0.0)
    std = jnp.sqrt(var)
    c = xs - mean[..., None]
    m3 = jnp.mean(c ** 3, -1)
    m4 = jnp.mean(c ** 4, -1)
    skew = m3 / jnp.maximum(std ** 3, EPS)
    kurt = m4 / jnp.maximum(var ** 2, EPS)
    return jnp.stack([mean, hmean, tmean, energy, entropy, mn, med, mx,
                      std, skew, q25, q75, iqr, jnp.abs(skew), kurt], axis=-1)


# ------------------------------------------------------------------- gram
def gram_ref(X):
    """X (n, F) -> X^T X in fp32."""
    Xf = X.astype(jnp.float32)
    return Xf.T @ Xf


# ------------------------------------------------------------------- hist
def hist_ref(bins, node, stat, n_nodes: int, n_bins: int):
    """Histogram h[s, b, :] = sum_i 1[node_i=s, bins_i=b] stat_i  (one
    feature column).  bins: (n,) int32; node: (n,) int32; stat: (n, C).
    Returns (n_nodes, n_bins, C) fp32."""
    ids = node * n_bins + bins
    return jax.ops.segment_sum(
        stat.astype(jnp.float32), ids, num_segments=n_nodes * n_bins
    ).reshape(n_nodes, n_bins, stat.shape[-1])


# --------------------------------------------------------- swa_attention
def swa_attention_ref(q, k, v, window: int, causal: bool = True):
    """Sliding-window attention oracle.  q: (B,S,H,D); k,v: (B,S,H,D)
    (per-head layout, kv already expanded to H heads).  fp32 softmax."""
    B, S, H, D = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * (D ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = kpos <= qpos if causal else jnp.ones((S, S), bool)
    if window:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None, None], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", a.astype(v.dtype), v)
