"""Tiled symmetric Gram accumulation X^T X (pl.pallas_call + BlockSpec).

The PCA/SVD hot loop (DESIGN §2): MXU-aligned 128x128 output tiles, fp32
accumulation over example chunks (grid dim 2 is the reduction — sequential
on TPU, so the output tile accumulates in VMEM and spills once).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_F = 128
TILE_N = 512


def _kernel(xi_ref, xj_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xi = xi_ref[...].astype(jnp.float32)                  # (TN, TF)
    xj = xj_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        xi, xj, (((0,), (0,)), ((), ())),                  # xi^T @ xj
        preferred_element_type=jnp.float32)


def gram_pallas(X, interpret: bool = True):
    """X (n, F) with n % TILE_N == 0 and F % TILE_F == 0 -> (F, F) fp32."""
    n, F = X.shape
    assert n % TILE_N == 0 and F % TILE_F == 0, (n, F)
    nf = F // TILE_F
    return pl.pallas_call(
        _kernel,
        grid=(nf, nf, n // TILE_N),
        in_specs=[
            pl.BlockSpec((TILE_N, TILE_F), lambda i, j, k: (k, i)),
            pl.BlockSpec((TILE_N, TILE_F), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((TILE_F, TILE_F), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((F, F), jnp.float32),
        interpret=interpret,
    )(X, X)
