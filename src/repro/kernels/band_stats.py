"""Fused 15-statistic EEG feature kernel (pl.pallas_call + BlockSpec).

Input rows arrive SORTED along time (XLA sort upstream), so order statistics
are indexed reads and everything else is a masked reduction — one VMEM pass
produces all 15 statistics per (epoch, band).  This is the TPU-native
adaptation of the paper's feature extractor (DESIGN §2): the hot loop is
(epochs x bands) independent reductions over 3000 samples, ideal VPU work;
fusing all 15 avoids re-streaming the 23 MB/1000-epoch band tensor 15x
from HBM.

Layout: x (N, BANDS, T_pad) fp32, T_pad a lane multiple (3000 -> 3072,
edge-padded with the row max so sortedness is preserved); out (N, BANDS, 16)
(15 stats + 1 pad column).  Grid tiles N; each program reduces a
(TILE_N, BANDS, T_pad) block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-9
TILE_N = 8
STAT_COLS = 16          # 15 stats, padded to 16


def _kernel(true_t: int, x_ref, o_ref):
    x = x_ref[...]                                        # (TB, 5, Tp)
    Tp = x.shape[-1]
    T = true_t
    mask = (jax.lax.broadcasted_iota(jnp.int32, x.shape, 2) < T)
    xm = jnp.where(mask, x, 0.0)
    fT = jnp.float32(T)

    s1 = jnp.sum(xm, -1)
    mean = s1 / fT
    s2 = jnp.sum(xm * xm, -1)
    energy = s2
    var = jnp.maximum(s2 / fT - mean * mean, 0.0)
    std = jnp.sqrt(var)

    hsum = jnp.sum(jnp.where(mask, 1.0 / (jnp.abs(x) + 1e-3), 0.0), -1)
    hmean = 1.0 / jnp.maximum(hsum / fT, EPS)

    p = (x * x) / jnp.maximum(energy[..., None], EPS)
    entropy = -jnp.sum(jnp.where(mask, p * jnp.log(p + EPS), 0.0), -1)

    i25 = (25 * (T - 1)) // 100
    i50 = (T - 1) // 2
    i75 = (75 * (T - 1)) // 100
    mn = x[..., 0]
    q25 = x[..., i25]
    med = x[..., i50]
    q75 = x[..., i75]
    mx = x[..., T - 1]
    iqr = q75 - q25

    # trimmed mean over sorted positions [i25, i75] (static range)
    tmask = (jax.lax.broadcasted_iota(jnp.int32, x.shape, 2) >= i25) & \
            (jax.lax.broadcasted_iota(jnp.int32, x.shape, 2) <= i75)
    tmean = jnp.sum(jnp.where(tmask, x, 0.0), -1) / jnp.float32(i75 - i25 + 1)

    c = jnp.where(mask, x - mean[..., None], 0.0)
    m3 = jnp.sum(c ** 3, -1) / fT
    m4 = jnp.sum(c ** 4, -1) / fT
    skew = m3 / jnp.maximum(std ** 3, EPS)
    kurt = m4 / jnp.maximum(var * var, EPS)

    stats = [mean, hmean, tmean, energy, entropy, mn, med, mx,
             std, skew, q25, q75, iqr, jnp.abs(skew), kurt,
             jnp.zeros_like(mean)]
    o_ref[...] = jnp.stack(stats, axis=-1)                # (TB, 5, 16)


def band_stats_pallas(xs, true_t: int, interpret: bool = True):
    """xs: (N, BANDS, T_pad) fp32 sorted+edge-padded.  -> (N, BANDS, 16)."""
    N, BANDS, Tp = xs.shape
    assert N % TILE_N == 0, f"N={N} not a multiple of {TILE_N}"
    return pl.pallas_call(
        functools.partial(_kernel, true_t),
        grid=(N // TILE_N,),
        in_specs=[pl.BlockSpec((TILE_N, BANDS, Tp), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((TILE_N, BANDS, STAT_COLS), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, BANDS, STAT_COLS), jnp.float32),
        interpret=interpret,
    )(xs)
