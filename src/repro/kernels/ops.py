"""jit'd public wrappers for the Pallas kernels (the ``ops.py`` contract).

Dispatch: on TPU the compiled kernels run natively; on CPU the default is
the jnp oracle (fast), with ``REPRO_KERNELS=interpret`` forcing the Pallas
kernel bodies through the interpreter (how the test suite validates them).
Wrappers own all shape padding/alignment so callers never see tile math.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.band_stats import TILE_N as BS_TILE, band_stats_pallas
from repro.kernels.gram import TILE_F, TILE_N as G_TILE, gram_pallas
from repro.kernels.hist import TILE_N as H_TILE, hist_pallas
from repro.kernels.swa_attention import BLOCK_Q, swa_attention_pallas

band_stats_ref = ref.band_stats_ref
gram_ref = ref.gram_ref
hist_ref = ref.hist_ref
swa_attention_ref = ref.swa_attention_ref


def _mode() -> str:
    env = os.environ.get("REPRO_KERNELS", "auto")
    if env != "auto":
        return env                       # ref | interpret | tpu
    return "tpu" if jax.default_backend() == "tpu" else "ref"


def _pad_to(x, axis: int, multiple: int, mode: str = "constant"):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x, x.shape[axis]
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, mode=("edge" if mode == "edge" else "constant")), \
        x.shape[axis]


@functools.partial(jax.jit, static_argnames=("force",))
def band_stats(xs_sorted, force: str = ""):
    """xs_sorted (N, BANDS, T) sorted ascending -> (N, BANDS, 15)."""
    mode = force or _mode()
    if mode == "ref":
        return band_stats_ref(xs_sorted)
    xp, true_t = _pad_to(xs_sorted, 2, 128, mode="edge")   # keep sortedness
    xp, true_n = _pad_to(xp, 0, BS_TILE)
    out = band_stats_pallas(xp, true_t, interpret=(mode != "tpu"))
    return out[:true_n, :, :15]


@functools.partial(jax.jit, static_argnames=("force",))
def gram(X, force: str = ""):
    """X (n, F) -> X^T X (F, F) fp32."""
    mode = force or _mode()
    if mode == "ref":
        return gram_ref(X)
    Xp, F = _pad_to(X.astype(jnp.float32), 1, TILE_F)
    Xp, _n = _pad_to(Xp, 0, G_TILE)                        # zero rows: no-op
    out = gram_pallas(Xp, interpret=(mode != "tpu"))
    return out[:F, :F]


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins", "force"))
def hist(bins, node, stat, n_nodes: int, n_bins: int, force: str = ""):
    """Histogram (n_nodes, n_bins, C) of per-example stats (one feature)."""
    mode = force or _mode()
    if mode == "ref":
        return hist_ref(bins, node, stat, n_nodes, n_bins)
    ids = (node * n_bins + bins).astype(jnp.int32)[:, None]
    idp, _ = _pad_to(ids, 0, H_TILE)
    # padded ids point at slot 0 with zero stat rows -> no contribution
    statp, _ = _pad_to(stat, 0, H_TILE)
    out = hist_pallas(idp, statp, n_nodes * n_bins,
                      interpret=(mode != "tpu"))
    return out.reshape(n_nodes, n_bins, stat.shape[-1])


@functools.partial(jax.jit, static_argnames=("window", "causal", "force"))
def swa_attention(q, k, v, window: int = 0, causal: bool = True,
                  force: str = ""):
    """q,k,v (B,S,H,D) per-head layout -> (B,S,H,D)."""
    mode = force or _mode()
    if mode == "ref":
        return swa_attention_ref(q, k, v, window, causal)
    B, S, H, D = q.shape
    if not causal:
        assert S % BLOCK_Q == 0, "non-causal path requires aligned S"
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    qf, kf, vf = fold(q), fold(k), fold(v)
    qp, true_s = _pad_to(qf, 1, BLOCK_Q)
    kp, _ = _pad_to(kf, 1, BLOCK_Q)
    vp, _ = _pad_to(vf, 1, BLOCK_Q)
    dp_q, true_d = _pad_to(qp, 2, 128)
    dp_k, _ = _pad_to(kp, 2, 128)
    dp_v, _ = _pad_to(vp, 2, 128)
    out = swa_attention_pallas(dp_q, dp_k, dp_v, window=window,
                               causal=causal, interpret=(mode != "tpu"),
                               scale=D ** -0.5)
    out = out[:, :true_s, :true_d]
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
